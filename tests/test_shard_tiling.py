"""Shard-aware Pallas tiling: kernel × mesh composition parity.

The tiling carries a leading vertex-shard axis ([S, NB, BE], see
`kernels/edge_relax`) and the kernel grid walks (shard, block); plans ride
into `shard_map` bodies as replicated arguments. Everything here pins the
two invariants that make `--backend pallas --mesh host` one configuration:

  1. the sweep result is bit-identical for every vertex-shard count S
     (destination blocks never straddle a shard boundary), and
  2. a Pallas plan inside a mesh produces bit-identical labellings,
     affected sets, and query answers to the unsharded jnp reference —
     including the per-shard rectangular minplus bound + pmin epilogue.

Like tests/test_shard.py, the in-process tests run on whatever host mesh
the environment provides (1 device under plain pytest, 8 under the CI
`mesh` job); instances use R=8 landmarks so plane counts divide any
device count up to 8. The subprocess test forces the 8-device platform
itself and drives the serving loop with --backend pallas against the BFS
oracle — the acceptance configuration end-to-end.
"""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.graphs import generators as gen
from repro.graphs.coo import INF_D, apply_batch, from_edges, make_batch
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.batch import batchhl_update
from repro.core.engine import JNP_PLAN, RelaxEngine, relax_sweep
from repro.core.labelling import INF_KEY2
from repro.core.query import batched_query
from repro.core.shard import shard_batched_query, shard_batchhl_update, \
    shard_build_labelling
from repro.kernels.minplus import kernel as mpk, ref as mpr
from repro.launch.mesh import make_host_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _instance(n=60, extra=70, r=8, seed=5):
    edges = gen.random_connected(n, extra_edges=extra, seed=seed)
    g = from_edges(n, edges, edges.shape[0] + 32)
    landmarks = select_landmarks_by_degree(g, r)
    return edges, g, landmarks


# --- invariant 1: the vertex-shard axis never changes results --------------

@pytest.mark.parametrize("shards", [1, 2, 3, 5])
@pytest.mark.parametrize("n,extra,bv", [(9, 4, 8), (57, 30, 16),
                                        (64, 40, 8)])
def test_sweep_parity_across_shard_counts(shards, n, extra, bv):
    edges = gen.random_connected(n, extra_edges=extra, seed=n + shards)
    g = from_edges(n, edges, edges.shape[0] + 32)
    plan = RelaxEngine(backend="pallas", block_v=bv,
                       shards=shards).prepare(g)
    assert plan.tiles.shards == shards
    rng = np.random.default_rng(n * 31 + shards)
    keys = jnp.asarray(rng.integers(0, 200, n).astype(np.int32))
    hub = jnp.asarray(rng.random(n) < 0.3)
    mask = jnp.asarray(rng.random(g.src.shape[0]) < 0.7) & g.valid
    want = relax_sweep(JNP_PLAN, g, keys, 2, int(INF_KEY2),
                       hub=hub, clear_bit=1, edge_mask=mask)
    got = relax_sweep(plan, g, keys, 2, int(INF_KEY2),
                      hub=hub, clear_bit=1, edge_mask=mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sharded_tiling_covers_all_edges():
    """Every occupied edge slot appears in exactly one tile slot, in
    whichever shard owns its destination block."""
    n, bv, shards = 57, 16, 3
    edges = gen.random_connected(n, extra_edges=40, seed=7)
    g = from_edges(n, edges, edges.shape[0] + 32)
    tiles = RelaxEngine(backend="pallas", block_v=bv,
                        shards=shards).prepare(g).tiles
    slot = np.asarray(tiles.slot_t)
    perm = np.asarray(tiles.perm_t)
    dstloc = np.asarray(tiles.dstloc_t)
    occupied = np.flatnonzero(np.asarray(g.valid))
    seen = perm[slot != 0]
    assert sorted(seen.tolist()) == sorted(occupied.tolist())
    # Destination reconstruction: shard/block owner matches the COO dst.
    s_idx, b_idx, e_idx = np.nonzero(slot)
    nb_loc = tiles.src_t.shape[1]
    flat_block = s_idx * nb_loc + b_idx
    dst = np.asarray(g.dst)[perm[s_idx, b_idx, e_idx]]
    np.testing.assert_array_equal(dst // bv, flat_block)
    np.testing.assert_array_equal(dst % bv, dstloc[s_idx, b_idx, e_idx])


# --- rectangular minplus: the per-shard query-bound contraction ------------

@pytest.mark.parametrize("b,p,r", [(1, 1, 1), (7, 3, 5), (64, 4, 16),
                                   (33, 128, 256), (257, 130, 64)])
def test_rectangular_minplus_kernel_parity(b, p, r):
    rng = np.random.default_rng(b * 100 + p + r)
    s = rng.integers(0, 1 << 20, (b, p)).astype(np.int32)
    h = rng.integers(0, 1 << 20, (p, r)).astype(np.int32)
    t = rng.integers(0, 1 << 20, (b, r)).astype(np.int32)
    s[rng.random((b, p)) < 0.3] = 1 << 29
    t[rng.random((b, r)) < 0.3] = 1 << 29
    got = mpk.minplus_pallas(jnp.asarray(s), jnp.asarray(h), jnp.asarray(t),
                             interpret=True)
    want = mpr.minplus_bound(jnp.asarray(s), jnp.asarray(h), jnp.asarray(t))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_minplus_shape_mismatch_raises():
    s = jnp.zeros((4, 3), jnp.int32)
    h = jnp.zeros((5, 7), jnp.int32)
    t = jnp.zeros((4, 7), jnp.int32)
    with pytest.raises(ValueError, match="shape mismatch"):
        mpk.minplus_pallas(s, h, t, interpret=True)


# --- invariant 2: pallas plans inside the mesh ≡ unsharded jnp -------------

def test_sharded_pallas_update_parity_host_mesh():
    """shard_batchhl_update with a real tiled plan ≡ unsharded jnp on
    every labelling field, the affected sets, and query answers (with the
    per-shard minplus kernel bound)."""
    mesh = make_host_mesh()
    edges, g, landmarks = _instance(seed=21)
    n = g.n
    lab = build_labelling(g, landmarks)
    ups = gen.random_batch_updates(edges, n, n_ins=4, n_del=4, seed=9)
    batch = make_batch(ups, pad_to=8)
    g_next = apply_batch(g, batch)
    engine = RelaxEngine(backend="pallas", block_v=16, shards=2)
    plan = engine.prepare(g_next)

    gj, labj, affj = batchhl_update(g, batch, lab, improved=True)
    sgp, labp, affp = shard_batchhl_update(mesh, g, batch, lab,
                                           plan=plan, g_new=g_next)
    np.testing.assert_array_equal(np.asarray(affp), np.asarray(affj))
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(np.asarray(getattr(labp, f)),
                                      np.asarray(getattr(labj, f)))

    rng = np.random.default_rng(3)
    qs = jnp.asarray(rng.integers(0, n, 29), jnp.int32)
    qt = jnp.asarray(rng.integers(0, n, 29), jnp.int32)
    want = batched_query(gj, labj, qs, qt)
    got = shard_batched_query(mesh, sgp, labp, qs, qt, use_kernel=True,
                              plan=plan)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sharded_pallas_construction_parity_host_mesh():
    mesh = make_host_mesh()
    _, g, landmarks = _instance(seed=31)
    plan = RelaxEngine(backend="pallas", block_v=16, shards=3).prepare(g)
    lab = build_labelling(g, landmarks)
    slab = shard_build_labelling(mesh, g, landmarks, plan=plan)
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(np.asarray(getattr(slab, f)),
                                      np.asarray(getattr(lab, f)))


def test_minplus_kernel_inside_shard_map():
    """The per-shard launch + pmin epilogue on the *kernel* path: an
    interpret-mode rectangular minplus inside a shard_map body over
    model-sharded highway rows must reproduce the full contraction."""
    import jax
    from functools import partial as fpartial
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.kernels.minplus import ops as minplus_ops

    mesh = make_host_mesh(model=len(jax.devices()))
    b, r = 13, 8
    rng = np.random.default_rng(2)
    s = jnp.asarray(rng.integers(0, 1000, (b, r)), jnp.int32)
    h = jnp.asarray(rng.integers(0, 1000, (r, r)), jnp.int32)
    t = jnp.asarray(rng.integers(0, 1000, (b, r)), jnp.int32)

    @fpartial(jax.jit, static_argnames=("mesh",))
    def sharded_bound(mesh, s, h, t):
        def body(s_loc, h_rows, t_full):
            part = minplus_ops.minplus_bound(s_loc, h_rows, t_full,
                                             use_pallas=True)
            return jax.lax.pmin(part, "model")

        return shard_map(body, mesh=mesh,
                         in_specs=(P(None, "model"), P("model"), P()),
                         out_specs=P(), check_rep=False)(s, h, t)

    want = mpr.minplus_bound(s, h, t)
    got = sharded_bound(mesh, s, h, t)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sharded_query_minplus_kernel_parity_host_mesh():
    """use_kernel=True (per-shard rectangular minplus + pmin epilogue)
    ≡ use_kernel=False ≡ unsharded, on the same labelling."""
    mesh = make_host_mesh()
    _, g, landmarks = _instance(seed=41)
    n = g.n
    lab = build_labelling(g, landmarks)
    rng = np.random.default_rng(4)
    qs = jnp.asarray(rng.integers(0, n, 17), jnp.int32)
    qt = jnp.asarray(rng.integers(0, n, 17), jnp.int32)
    want = batched_query(g, lab, qs, qt)
    got_jnp = shard_batched_query(mesh, g, lab, qs, qt, use_kernel=False)
    got_krn = shard_batched_query(mesh, g, lab, qs, qt, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(got_jnp), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_krn), np.asarray(want))


# --- acceptance configuration end-to-end (forced 8-device subprocess) ------

@pytest.mark.slow
def test_serve_pallas_mesh_multidevice():
    """`--backend pallas --mesh host` on a (data=4, model=2) 8-device CPU
    mesh: the Pallas kernel runs per shard (tile-shards=2 grid), the
    minplus kernel bounds the queries, and every answer matches the BFS
    oracle."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--n", "300", "--batches", "2", "--batch-size", "30",
         "--queries", "48", "--landmarks", "8",
         "--mesh", "host", "--shards", "2",
         "--backend", "pallas", "--tile-shards", "2",
         "--use-minplus-kernel", "--verify"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "serve loop done [backend=pallas" in out.stdout, out.stdout
    assert "tile-shards=2" in out.stdout, out.stdout
    assert out.stdout.count("verify: 0/48 mismatches") == 2, out.stdout
