"""Train a ~100M-param Gemma-2-style LM for a few hundred steps on CPU,
with checkpoint/restart fault tolerance (kill it and rerun — it resumes).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

This is the reduced-scale twin of the pod-scale train_4k cell: the same
train_step factory, optimizer, and checkpoint manager that the dry-run
lowers for 256/512 chips, running end-to-end on one CPU device.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import TransformerConfig, init_params
from repro.train.optimizer import AdamWConfig
from repro.train import train_step as ts_lib
from repro.checkpoint import manager as ckpt


def config_100m() -> TransformerConfig:
    return TransformerConfig(
        name="gemma2-100m", n_layers=6, d_model=512, n_heads=8,
        n_kv_heads=4, d_head=64, d_ff=2048, vocab=32000,
        attn_pattern="local_global", window=256,
        attn_softcap=50.0, final_softcap=30.0, act="gelu",
        dtype=jnp.float32, q_chunk=128, kv_chunk=128, loss_chunk=128)


def batch_fn(step: int, batch: int, seq: int, vocab: int) -> dict:
    rng = np.random.default_rng(step)
    # skewed unigram stream so the model has something to learn
    toks = (rng.zipf(1.5, size=(batch, seq + 1)) % vocab).astype(np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "targets": jnp.asarray(toks[:, 1:])}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    cfg = config_100m()
    n_params = cfg.params_count
    print(f"model: {n_params / 1e6:.0f}M params")
    opt = AdamWConfig(lr=3e-4)
    step_fn = jax.jit(ts_lib.make_lm_train_step(cfg, opt, microbatch=2))

    params = init_params(jax.random.PRNGKey(0), cfg)
    start = ckpt.latest_step(args.ckpt_dir)
    if start is not None:
        state, start = ckpt.restore(args.ckpt_dir,
                                    ts_lib.init_train_state(params, opt))
        print(f"resumed at step {start}")
    else:
        state, start = ts_lib.init_train_state(params, opt), 0

    t0 = time.time()
    for step in range(start, args.steps):
        state, aux = step_fn(state, batch_fn(step, args.batch, args.seq,
                                             cfg.vocab))
        if step % 20 == 0:
            tput = args.batch * args.seq / max(time.time() - t0, 1e-9)
            print(f"step {step:4d}  loss {float(aux['loss']):.4f}  "
                  f"({tput:.0f} tok/s)")
            t0 = time.time()
        if (step + 1) % 100 == 0:
            ckpt.save(args.ckpt_dir, step + 1, state)
            ckpt.prune(args.ckpt_dir, keep=2)
    print(f"final loss {float(aux['loss']):.4f}")


if __name__ == "__main__":
    main()
