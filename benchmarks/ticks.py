"""Serving-tick latency trajectory: backend × mesh, the CI bench preset.

The scale story of this repo lives or dies on two numbers per tick — the
batch-update latency and the query-batch latency — across the four
backend × mesh configurations that PRs 1–3 built:

    ticks/<dataset>/<backend>/<mesh>/construct   (one-off, seconds→us)
    ticks/<dataset>/<backend>/<mesh>/update      (median per-tick)
    ticks/<dataset>/<backend>/<mesh>/query       (median per-tick)

Rows follow the ``name,us_per_call,derived`` contract of benchmarks/run.py;
``python -m benchmarks.run --preset quick --json BENCH_pr3.json`` persists
them in the bench-trajectory JSON format that `benchmarks/compare.py`
gates against the committed `benchmarks/baseline.json` (>25% tick-latency
regressions fail the CI `bench` job).

The quick preset is sized for shared CI runners: one small dataset, a few
ticks, the degenerate host mesh on however many devices the runner
exposes. The point is the *trajectory* (same shapes every PR), not
absolute hardware truth.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import DATASETS, emit
from repro.graphs import generators as gen
from repro.graphs.coo import apply_batch, from_edges, make_batch
from repro.core.batch import batchhl_update
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.engine import RelaxEngine
from repro.core.query import batched_query
from repro.core.shard import (shard_batched_query, shard_batchhl_update,
                              shard_build_labelling)
from repro.launch.mesh import make_host_mesh


def _tick_loop(name: str, g0, landmarks, edges, backend: str, mesh,
               ticks: int, batch_size: int, queries: int,
               block_v: int, tile_shards: int) -> list[str]:
    n = g0.n
    engine = RelaxEngine(backend=backend, block_v=block_v,
                         shards=tile_shards)
    plan = engine.prepare(g0)

    t0 = time.time()
    if mesh is None:
        lab = build_labelling(g0, landmarks, plan=plan)
    else:
        lab = shard_build_labelling(mesh, g0, landmarks, plan=plan)
    jax.block_until_ready(lab.dist)
    rows = [emit(f"{name}/construct", time.time() - t0, f"R={len(landmarks)}")]

    rng = np.random.default_rng(11)
    g, cur_edges = g0, edges
    t_upd, t_q = [], []
    for tick in range(ticks):
        ups = gen.random_batch_updates(cur_edges, n, n_ins=batch_size // 2,
                                       n_del=batch_size // 2,
                                       seed=500 + tick)
        batch = make_batch(ups, pad_to=batch_size)
        has_ins = any(not d for (_, _, d) in ups)
        t0 = time.time()
        g_next = apply_batch(g, batch)
        plan = engine.prepare(g_next, topology_changed=has_ins)
        if mesh is None:
            g, lab, aff = batchhl_update(g, batch, lab, improved=True,
                                         plan=plan, g_new=g_next)
        else:
            g, lab, aff = shard_batchhl_update(mesh, g, batch, lab,
                                               improved=True, plan=plan,
                                               g_new=g_next)
        jax.block_until_ready(lab.dist)
        t_upd.append(time.time() - t0)

        qs = jnp.asarray(rng.integers(0, n, queries), jnp.int32)
        qt = jnp.asarray(rng.integers(0, n, queries), jnp.int32)
        t0 = time.time()
        if mesh is None:
            d = batched_query(g, lab, qs, qt, plan=plan)
        else:
            d = shard_batched_query(mesh, g, lab, qs, qt, plan=plan)
        jax.block_until_ready(d)
        t_q.append(time.time() - t0)

        # Fold this tick's updates into the edge set for the next one.
        es = {(int(min(u, v)), int(max(u, v))) for u, v in cur_edges}
        for u, v, is_del in ups:
            k = (min(u, v), max(u, v))
            es.discard(k) if is_del else es.add(k)
        cur_edges = np.asarray(sorted(es), np.int32)

    # Min of the steady-state ticks: tick 0 pays compilation and tick 1
    # can pay a second trace (the labelling comes back mesh-sharded after
    # the first update), so both are warmup; min (not median) because a
    # transient load burst on a shared runner inflates several consecutive
    # ticks at once, and the fastest tick is the best estimate of the
    # unloaded latency the gate should track.
    warm = 2 if ticks > 2 else 1 if ticks > 1 else 0
    steady_upd = t_upd[warm:]
    steady_q = t_q[warm:]
    rows.append(emit(f"{name}/update", float(np.min(steady_upd)),
                     f"stat=min;ticks={ticks};batch={batch_size}"))
    rows.append(emit(f"{name}/query", float(np.min(steady_q)),
                     f"stat=min;ticks={ticks};B={queries}"))
    return rows


def run(datasets=("ba_2k",), backends=("jnp", "pallas"),
        meshes=("none", "host"), ticks: int = 6, batch_size: int = 64,
        queries: int = 128, landmarks: int = 16, block_v: int = 256,
        tile_shards: int = 2) -> list[str]:
    rows = []
    for ds in datasets:
        edges = DATASETS[ds]()
        n = int(edges.max()) + 1
        cap = edges.shape[0] + ticks * batch_size + 64
        g0 = from_edges(n, edges, cap)
        lms = select_landmarks_by_degree(g0, landmarks)
        for backend in backends:
            for mesh_name in meshes:
                mesh = make_host_mesh() if mesh_name == "host" else None
                rows += _tick_loop(f"ticks/{ds}/{backend}/{mesh_name}",
                                   g0, lms, edges, backend, mesh, ticks,
                                   batch_size, queries, block_v, tile_shards)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
