"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests only; optional dep
pytestmark = pytest.mark.slow  # property tests: full CI job only
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.kernels.minplus import kernel as mpk, ref as mpr
from repro.kernels.edge_relax import kernel as erk, ops as ero, ref as err
from repro.kernels.embed_bag import kernel as ebk, ref as ebr

INF = 1 << 29
SETTINGS = dict(deadline=None, max_examples=15,
                suppress_health_check=[HealthCheck.too_slow])


# --- minplus ---------------------------------------------------------------

@pytest.mark.parametrize("b,r", [(1, 1), (7, 3), (64, 20), (300, 33),
                                 (257, 128), (512, 129)])
def test_minplus_shapes(b, r):
    rng = np.random.default_rng(b * 1000 + r)
    s = rng.integers(0, 100, (b, r)).astype(np.int32)
    h = rng.integers(0, 100, (r, r)).astype(np.int32)
    t = rng.integers(0, 100, (b, r)).astype(np.int32)
    s[rng.random((b, r)) < 0.3] = INF
    t[rng.random((b, r)) < 0.3] = INF
    got = mpk.minplus_pallas(jnp.asarray(s), jnp.asarray(h), jnp.asarray(t),
                             interpret=True)
    want = mpr.minplus_bound(jnp.asarray(s), jnp.asarray(h), jnp.asarray(t))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), b=st.integers(1, 80),
       r=st.integers(1, 40))
def test_minplus_property(seed, b, r):
    rng = np.random.default_rng(seed)
    s = rng.integers(0, 1 << 20, (b, r)).astype(np.int32)
    h = rng.integers(0, 1 << 20, (r, r)).astype(np.int32)
    t = rng.integers(0, 1 << 20, (b, r)).astype(np.int32)
    got = mpk.minplus_pallas(jnp.asarray(s), jnp.asarray(h), jnp.asarray(t),
                             interpret=True)
    want = mpr.minplus_bound(jnp.asarray(s), jnp.asarray(h), jnp.asarray(t))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --- edge_relax ------------------------------------------------------------

@pytest.mark.parametrize("n,e,bv", [(16, 40, 8), (300, 1200, 64),
                                    (1000, 5000, 128), (77, 200, 32)])
def test_edge_relax_shapes(n, e, bv):
    rng = np.random.default_rng(n + e)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    valid = rng.random(e) < 0.8
    keys = rng.integers(0, 1 << 20, n).astype(np.int32)
    bg = ero.prepare(src, dst, valid, n, block_v=bv)
    got = erk.edge_relax_pallas(jnp.asarray(keys), bg.src_t, bg.dstloc_t,
                                bg.valid_t, 2, bg.n, bg.block_v,
                                interpret=True)
    want = err.edge_relax(jnp.asarray(keys), jnp.asarray(src),
                          jnp.asarray(dst), jnp.asarray(valid), 2, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 200),
       e=st.integers(1, 600))
def test_edge_relax_property(seed, n, e):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    valid = rng.random(e) < 0.7
    keys = rng.integers(0, 1 << 20, n).astype(np.int32)
    bg = ero.prepare(src, dst, valid, n, block_v=32)
    got = ero.edge_relax(jnp.asarray(keys), bg, 2, use_pallas=True)
    want = err.edge_relax(jnp.asarray(keys), jnp.asarray(src),
                          jnp.asarray(dst), jnp.asarray(valid), 2, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --- embed_bag -------------------------------------------------------------

@pytest.mark.parametrize("n,d,b,l", [(100, 8, 16, 3), (500, 64, 100, 7),
                                     (50, 128, 130, 20), (1000, 32, 64, 50)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_embed_bag_shapes(n, d, b, l, dtype):
    rng = np.random.default_rng(n + d)
    table = rng.normal(size=(n, d)).astype(dtype)
    idx = rng.integers(0, n, (b, l)).astype(np.int32)
    w = rng.random((b, l)).astype(np.float32)
    got = ebk.embed_bag_pallas(jnp.asarray(table), jnp.asarray(idx),
                               jnp.asarray(w), interpret=True)
    want = ebr.embed_bag(jnp.asarray(table), jnp.asarray(idx),
                         jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 300),
       d=st.integers(1, 64), b=st.integers(1, 60), l=st.integers(1, 16))
def test_embed_bag_property(seed, n, d, b, l):
    rng = np.random.default_rng(seed)
    table = rng.normal(size=(n, d)).astype(np.float32)
    idx = rng.integers(0, n, (b, l)).astype(np.int32)
    w = rng.random((b, l)).astype(np.float32)
    got = ebk.embed_bag_pallas(jnp.asarray(table), jnp.asarray(idx),
                               jnp.asarray(w), interpret=True)
    want = ebr.embed_bag(jnp.asarray(table), jnp.asarray(idx),
                         jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_embed_bag_masked_mean():
    from repro.kernels.embed_bag import ops as ebo
    rng = np.random.default_rng(0)
    table = rng.normal(size=(50, 8)).astype(np.float32)
    idx = rng.integers(0, 50, (10, 5)).astype(np.int32)
    mask = rng.random((10, 5)) < 0.6
    got = ebo.embed_bag(jnp.asarray(table), jnp.asarray(idx),
                        jnp.asarray(mask), mode="mean", use_pallas=True)
    # manual oracle
    want = np.zeros((10, 8), np.float32)
    for b in range(10):
        rows = [table[idx[b, j]] for j in range(5) if mask[b, j]]
        if rows:
            want[b] = np.mean(rows, axis=0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)
