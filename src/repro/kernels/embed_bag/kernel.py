"""Embedding-bag kernel: weighted gather-reduce over a sharded table.

    out[b, :] = Σ_l  w[b, l] · table[idx[b, l], :]

JAX has no native EmbeddingBag; this is the framework's own (taxonomy
§B.6 — the recsys hot path, also reused as the GNN neighbor-feature
gather). The batch axis is tiled; each grid step gathers its [BB, L] bag
rows from the VMEM-resident table shard and contracts the bag axis with
the per-sample weights — the contraction maps onto the MXU as a
[BB, L] × [L·gather] weighted reduce realized via einsum.

Production layout: the table is row-sharded over the mesh (`model`×`data`);
each device's shard (rows_local × D ≤ a few MB after sharding a 10⁷-row
table 256-way) fits VMEM; out-of-shard indices are masked to row 0 with
weight 0 by the ops wrapper, and partial bags are summed with psum — the
standard sharded-embedding reduce-scatter pattern.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BB = 128


def _embed_bag_kernel(table_ref, idx_ref, w_ref, o_ref):
    table = table_ref[...]        # [N, D] (device shard)
    idx = idx_ref[...]            # [BB, L]
    w = w_ref[...]                # [BB, L]
    rows = jnp.take(table, idx.reshape(-1), axis=0)          # [BB*L, D]
    rows = rows.reshape(idx.shape[0], idx.shape[1], -1)      # [BB, L, D]
    o_ref[...] = jnp.einsum("bl,bld->bd", w, rows,
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def embed_bag_pallas(table: jax.Array, idx: jax.Array, weights: jax.Array,
                     block_b: int = DEFAULT_BB,
                     interpret: bool = True) -> jax.Array:
    """table [N,D] f32, idx [B,L] int32, weights [B,L] f32 → [B,D] f32."""
    b, l = idx.shape
    n, d = table.shape
    bp = -(-b // block_b) * block_b
    idx_p = jnp.zeros((bp, l), jnp.int32).at[:b].set(idx)
    w_p = jnp.zeros((bp, l), weights.dtype).at[:b].set(weights)

    out = pl.pallas_call(
        _embed_bag_kernel,
        grid=(bp // block_b,),
        in_specs=[
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((block_b, l), lambda i: (i, 0)),
            pl.BlockSpec((block_b, l), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, d), jnp.float32),
        interpret=interpret,
    )(table.astype(jnp.float32), idx_p, w_p)
    return out[:b]
