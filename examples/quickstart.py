"""Quickstart: build a highway-cover labelling, apply a batch update,
answer exact distance queries — the paper's pipeline through the public
façade (`repro.api`) in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import api
from repro.graphs import generators as gen

# 1. a small-diameter complex network (Barabási–Albert, like the paper's)
n = 5_000
edges = gen.barabasi_albert(n, 4, seed=0)

# 2. offline: pick high-degree landmarks, build the minimal labelling
g, lab = api.build(n, edges, num_landmarks=16)
print(f"labelling built: {int(lab.label_size())} entries "
      f"({int(lab.label_size()) / n:.2f} per vertex, R=16)")

# 3. online: a mixed batch of edge insertions + deletions (BatchHL)
updates = gen.random_batch_updates(edges, n, n_ins=50, n_del=50, seed=1)
g, lab, affected = api.update(g, lab, updates, pad_to=100)
print(f"batch of 100 updates applied; "
      f"{int(affected.sum())} (landmark, vertex) pairs affected")

# 4. answer exact distance queries on the updated graph
rng = np.random.default_rng(0)
s, t = rng.integers(0, n, 8), rng.integers(0, n, 8)
dist = api.query(g, lab, s, t)
for i in range(8):
    d = int(dist[i])
    print(f"d({int(s[i])}, {int(t[i])}) = {'inf' if d > n else d}")
