"""Paper Table 3: batch update time — BHL⁺ vs BHL vs BHLˢ vs UHL⁺ across
fully-dynamic / incremental / decremental settings, per sweep backend.

The headline claim reproduced here: batch-dynamic variants beat the
single-update loop (UHL⁺) by a wide margin because one vertex affected by
many updates is searched/repaired once, not once per update.

Every batched variant is timed once per relaxation-engine backend
(``jnp`` = XLA segment-min reference, ``pallas`` = tiled edge_relax
kernel — interpret-mode off TPU, compiled on TPU). For BHL⁺/BHL the
tiling is prepared outside the timed region exactly as the serving loop
amortizes it; BHLˢ inherently re-tiles per insertion sub-batch inside the
engine contract, so its pallas rows *include* that host tiling cost (the
row is tagged ``retiles_inside``). UHL⁺ is jnp-only: its per-update
re-tiling changes tile shapes and forces recompiles, so kernel throughput
is not what it would measure.
"""
from __future__ import annotations

from repro.graphs.coo import apply_batch, make_batch
from repro.core.batch import (batchhl_update, batchhl_update_split,
                              uhl_update)
from repro.core.engine import RelaxEngine
from benchmarks import common as cm

BATCH = 128
DATASETS = ("ba_2k", "ba_10k", "er_5k")
MODES = ("mixed", "incremental", "decremental")
BACKENDS = ("jnp", "pallas")


def run(datasets=DATASETS, batch=BATCH, unit_updates: int = 16,
        backends=BACKENDS) -> list[str]:
    rows = []
    for ds in datasets:
        inst = cm.build_instance(ds)
        for mode in MODES:
            ups = cm.update_stream(inst.edges, inst.n, batch, mode, seed=7)
            b = make_batch(ups, pad_to=batch)

            for backend in backends:
                engine = (RelaxEngine(backend=backend)
                          if backend != "jnp" else None)
                plan = (engine.prepare(apply_batch(inst.g, b))
                        if engine else None)
                t_bhlp = cm.timeit(
                    lambda: batchhl_update(inst.g, b, inst.lab,
                                           improved=True, plan=plan))
                rows.append(cm.emit(f"table3/{ds}/{mode}/BHL+/{backend}",
                                    t_bhlp, f"batch={batch}"))
                t_bhl = cm.timeit(
                    lambda: batchhl_update(inst.g, b, inst.lab,
                                           improved=False, plan=plan))
                rows.append(cm.emit(f"table3/{ds}/{mode}/BHL/{backend}",
                                    t_bhl, f"batch={batch}"))
                t_s = cm.timeit(
                    lambda: batchhl_update_split(inst.g, b, inst.lab,
                                                 engine=engine))
                split_note = (f"batch={batch}" if engine is None
                              else f"batch={batch};retiles_inside=1")
                rows.append(cm.emit(f"table3/{ds}/{mode}/BHLs/{backend}",
                                    t_s, split_note))

            # UHL+ on a prefix of the batch, scaled to the full batch size
            small = make_batch(ups[:unit_updates], pad_to=unit_updates)
            t_u = cm.timeit(
                lambda: uhl_update(inst.g, small, inst.lab), iters=1)
            t_u_scaled = t_u * batch / unit_updates
            rows.append(cm.emit(f"table3/{ds}/{mode}/UHL+/jnp", t_u_scaled,
                                f"scaled_from={unit_updates}"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--datasets", default=",".join(DATASETS))
    ap.add_argument("--backends", default=",".join(BACKENDS))
    ap.add_argument("--batch", type=int, default=BATCH)
    a = ap.parse_args()
    run(datasets=tuple(a.datasets.split(",")), batch=a.batch,
        backends=tuple(a.backends.split(",")))
