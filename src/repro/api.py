"""The public face of the repro: build / update / query / serve.

Four verbs cover the paper's whole lifecycle (BatchHL, arXiv 2204.11012):

    >>> from repro import api
    >>> g, lab = api.build(n, edges, num_landmarks=16)
    >>> g, lab, affected = api.update(g, lab, updates)
    >>> dist = api.query(g, lab, sources, targets)

and for the online story, a serve entry point whose *process topology is
configuration*: the same `ServeSpec` drives a single in-process loop or
a 1-updater + N-reader replica tier (`api.serve`).

Everything here is a thin, stable wrapper over the library modules —
`repro.graphs.coo`, `repro.core.{construct,batch,query}`, and
`repro.launch.{config,serve,replica}` own the machinery. Scripts that
need knobs beyond these signatures (custom relax plans, sharding,
kernels) should import those modules directly; this façade trades
surface for stability.
"""
from __future__ import annotations

import numpy as np

from repro.core.batch import batchhl_update
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.labelling import HighwayLabelling
from repro.core.query import batched_query
from repro.graphs.coo import BatchUpdate, Graph, from_edges, make_batch
from repro.launch.config import (CheckpointSpec, EngineSpec, GraphSpec,
                                 ServeSpec, StreamSpec, TopologySpec)

__all__ = [
    "build", "update", "query", "serve",
    "Graph", "BatchUpdate", "HighwayLabelling",
    "ServeSpec", "GraphSpec", "EngineSpec", "StreamSpec",
    "CheckpointSpec", "TopologySpec",
]


def build(n: int, edges: np.ndarray, *, num_landmarks: int = 16,
          landmarks=None, capacity: int | None = None,
          slack: int = 256) -> tuple[Graph, HighwayLabelling]:
    """Construct a dynamic graph and its highway-cover labelling.

    `edges` is an (E, 2) or (E, 3) int array of undirected edges
    (optional third column: positive integer weights). `capacity`
    reserves COO slots for future insertions (default: E + `slack`).
    Landmarks default to the paper's policy — the `num_landmarks`
    highest-degree vertices — or pass an explicit int array.

    Returns `(graph, labelling)`, the pair every other verb consumes.
    """
    edges = np.asarray(edges)
    g = from_edges(n, edges,
                   capacity=capacity or edges.shape[0] + slack)
    if landmarks is None:
        landmarks = select_landmarks_by_degree(g, k=num_landmarks)
    else:
        import jax.numpy as jnp
        landmarks = jnp.asarray(landmarks, jnp.int32)
    return g, build_labelling(g, landmarks)


def update(g: Graph, lab: HighwayLabelling, updates, *,
           improved: bool = True, pad_to: int | None = None
           ) -> tuple[Graph, HighwayLabelling, np.ndarray]:
    """Apply one batch of edge updates and repair the labelling (BatchHL).

    `updates` is a sequence of `(op, u, v)` or `(op, u, v, w)` rows
    (op: +1 insert, -1 delete, 0 re-weight) or an already-padded
    `BatchUpdate`. `pad_to` fixes the batch width so repeated calls with
    the same width reuse one compiled update (the serving pattern).
    `improved=True` selects the BHL⁺ search with landmark-distance
    pruning; `False` the basic variant.

    Returns `(graph', labelling', affected)` — `affected` is the boolean
    (R, n) plane of (landmark, vertex) pairs the repair recomputed.
    """
    batch = updates if isinstance(updates, BatchUpdate) \
        else make_batch(updates, pad_to=pad_to)
    g, lab, aff = batchhl_update(g, batch, lab, improved=improved)
    return g, lab, np.asarray(aff)


def query(g: Graph, lab: HighwayLabelling, s, t, *,
          max_steps: int = 64) -> np.ndarray:
    """Exact batched distances d_G(s, t) (paper §4: sparse BiBFS under a
    landmark upper bound). `s`/`t` are equal-length int vertex arrays;
    unreachable pairs come back as a value > any finite distance
    (compare with `np.inf` semantics via `d >= 10**9`)."""
    import jax.numpy as jnp
    s = jnp.asarray(np.asarray(s, np.int32))
    t = jnp.asarray(np.asarray(t, np.int32))
    return np.asarray(batched_query(g, lab, s, t, max_steps=max_steps))


def serve(spec: ServeSpec | None = None, *, publish_dir: str | None = None,
          **overrides) -> None:
    """Run the online serving story for a `ServeSpec`.

    Process topology is configuration: with `publish_dir=None` (default)
    this runs the single-process `ServeLoop` — updates and queries
    interleaved in one process. With a `publish_dir`, it deploys the
    replica tier (`repro.launch.replica`): one updater process
    publishing versions into `publish_dir`, `spec.topology.readers`
    reader processes mapping them, a coalescing router in front, and an
    open-loop client stream driven through it.

    `overrides` are `ServeSpec` group fields by name (`n=5000`,
    `readers=4`, `verify=True`, ...) applied over `spec` (or over the
    defaults when `spec is None`).
    """
    import dataclasses

    from repro.launch import replica
    from repro.launch.serve import ServeLoop

    spec = spec or ServeSpec()
    if overrides:
        groups = {}
        for gname, cls in (("graph", GraphSpec), ("engine", EngineSpec),
                           ("stream", StreamSpec),
                           ("checkpoint", CheckpointSpec),
                           ("topology", TopologySpec)):
            fields = {f.name for f in dataclasses.fields(cls)}
            got = {k: overrides.pop(k) for k in list(overrides)
                   if k in fields}
            if got:
                groups[gname] = dataclasses.replace(
                    getattr(spec, gname), **got)
        if overrides:
            raise TypeError(f"unknown serve() overrides: "
                            f"{sorted(overrides)}")
        spec = dataclasses.replace(spec, **groups)
    if publish_dir is None:
        ServeLoop(spec.to_serve_config()).run()
    else:
        replica.serve_main(spec, publish_dir, verify_limit=None)
