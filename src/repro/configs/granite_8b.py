"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152 — llama-arch, code [arXiv:2405.04324; hf]."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

ARCH_ID = "granite-8b"
FAMILY = "lm"
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def model_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
        d_head=128, d_ff=14336, vocab=49152,
        attn_pattern="full", act="silu", gated=True,
        rope_theta=10000.0, dtype=jnp.bfloat16)


def reduced_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_model=64, n_heads=8,
        n_kv_heads=2, d_head=8, d_ff=160, vocab=512, attn_pattern="full",
        act="silu", gated=True, dtype=jnp.float32,
        q_chunk=16, kv_chunk=16, loss_chunk=16)
