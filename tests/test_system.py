"""End-to-end behaviour of the paper's system: the distance-query service
(construct → batch updates → exact queries → checkpoint/restore), plus a
host-mesh sanity pass of the dry-run cell builder."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs import generators as gen
from repro.graphs.coo import from_edges, make_batch, to_numpy_adj, INF_D
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.batch import batchhl_update
from repro.core.query import batched_query
from repro.core import ref
from repro.checkpoint import manager as ckpt
from repro.core.labelling import HighwayLabelling


def test_service_lifecycle(tmp_path):
    """The full BatchHL serving loop on a power-law graph, verified
    against the oracle at every tick, surviving a checkpoint restart."""
    n = 400
    edges = gen.barabasi_albert(n, 3, seed=0)
    g = from_edges(n, edges, edges.shape[0] + 200)
    landmarks = select_landmarks_by_degree(g, 8)
    lab = build_labelling(g, landmarks)
    size0 = int(lab.label_size())
    assert 0 < size0 <= 8 * n

    rng = np.random.default_rng(0)
    cur_edges = edges
    for tick in range(3):
        ups = gen.random_batch_updates(cur_edges, n, n_ins=10, n_del=10,
                                       seed=tick + 50)
        batch = make_batch(ups, pad_to=20)
        g, lab, aff = batchhl_update(g, batch, lab, improved=True)

        adj = to_numpy_adj(g)
        qs = rng.integers(0, n, 32).astype(np.int32)
        qt = rng.integers(0, n, 32).astype(np.int32)
        got = np.asarray(batched_query(g, lab, jnp.asarray(qs),
                                       jnp.asarray(qt)))
        for k in range(32):
            want = ref.pair_distance(adj, n, int(qs[k]), int(qt[k]))
            want = 0 if qs[k] == qt[k] else want
            want = int(INF_D) if want == ref.INF else want
            assert got[k] == want

        # labelling minimality is preserved across ticks
        od, oh, ohw, omask = ref.minimal_labelling(
            adj, n, [int(x) for x in np.asarray(landmarks)])
        assert int(lab.label_size()) == int(np.sum(omask))

        adjset = {(min(a, b), max(a, b)) for a, b in cur_edges}
        for u, v, is_del in ups:
            key = (min(u, v), max(u, v))
            adjset.discard(key) if is_del else adjset.add(key)
        cur_edges = np.asarray(sorted(adjset), np.int32)

    # checkpoint the labelling, restore, answer again — identical
    d = str(tmp_path / "service")
    ckpt.save(d, 3, {"dist": lab.dist, "hub": lab.hub,
                     "highway": lab.highway, "landmarks": lab.landmarks})
    like = {"dist": jnp.zeros_like(lab.dist),
            "hub": jnp.zeros_like(lab.hub),
            "highway": jnp.zeros_like(lab.highway),
            "landmarks": jnp.zeros_like(lab.landmarks)}
    restored, _ = ckpt.restore(d, like)
    lab2 = HighwayLabelling(**restored)
    qs = jnp.asarray(rng.integers(0, n, 16), jnp.int32)
    qt = jnp.asarray(rng.integers(0, n, 16), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(batched_query(g, lab, qs, qt)),
        np.asarray(batched_query(g, lab2, qs, qt)))


def test_labelling_size_stays_bounded():
    """Paper §7.2.2: labelling size is bounded by R·V and stays stable
    under churn (minimality prevents monotone growth)."""
    n = 300
    edges = gen.barabasi_albert(n, 3, seed=1)
    g = from_edges(n, edges, edges.shape[0] + 400)
    landmarks = select_landmarks_by_degree(g, 6)
    lab = build_labelling(g, landmarks)
    sizes = [int(lab.label_size())]
    cur_edges = edges
    for tick in range(4):
        ups = gen.random_batch_updates(cur_edges, n, n_ins=15, n_del=15,
                                       seed=tick + 99)
        g, lab, _ = batchhl_update(g, make_batch(ups, pad_to=30), lab)
        sizes.append(int(lab.label_size()))
        adjset = {(min(a, b), max(a, b)) for a, b in cur_edges}
        for u, v, is_del in ups:
            key = (min(u, v), max(u, v))
            adjset.discard(key) if is_del else adjset.add(key)
        cur_edges = np.asarray(sorted(adjset), np.int32)
    assert all(s <= 6 * n for s in sizes)
    assert max(sizes) - min(sizes) < n  # stable, no runaway growth


def test_cell_builder_structures():
    """Cell arg specs and sharding specs must be structurally consistent
    for every (arch × shape) — catches registry/layout drift without
    compiling anything."""
    from repro.configs import common as cc
    for arch in cc.ALL_ARCHS:
        for shape in cc.arch_shapes(arch):
            cell = cc.build_cell(arch, shape, pod=False)
            assert len(cell.arg_specs) == len(cell.in_specs), (arch, shape)
            for args, specs in zip(cell.arg_specs, cell.in_specs):
                jax.tree.map(
                    lambda a, s: None, args, specs,
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
                    or hasattr(x, "_partitions"))
