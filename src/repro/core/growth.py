"""Grow-in-place capacity management (DESIGN.md §6).

The substrate's static shapes are what make every sweep one compiled
executable — but a batch-dynamic stream has no natural size bound, and a
fixed edge capacity chosen up front caps every run (the paper's premise
is graphs that "undergo rapid changes over time"; the incremental
labelling line it builds on is explicitly motivated by graphs that only
ever grow). This module removes the cap without giving up static shapes:
when a batch would overflow edge slots or introduce vertex ids >= n, the
slot arrays and labelling planes grow *geometrically* to the next
aligned size, and the serve loop commits the grown arrays as a new
version through the snapshot store's pointer swap — queries keep
answering against the committed pre-growth snapshot throughout, with the
same staleness <= 1 contract.

The contract, layer by layer:

* **Detection is host-side and pre-dispatch** (`ensure_capacity` →
  `coo.batch_requirements`): overflow surfaces as a typed
  `CapacityError` naming the tick and required sizes, never as a clobbered
  slot or a shape error from inside jit.
* **Growth is a pure shape change** (`coo.grow` + `grow_labelling` +
  `snapshot.grow_snapshot`): same edges, same distances; new edge slots
  are free, new vertex columns are isolated (dist INF_D, hub False) —
  exactly the state a fresh construction at the grown size assigns them,
  which is why post-growth maintenance stays bit-identical to fresh
  construction at the final size (pinned by `tests/test_growth.py`).
* **Geometric steps, aligned sizes** (`GrowthPolicy`): each growth at
  least multiplies the overflowing dimension by `factor`, so a stream of
  U-sized batches pays O(log(final/initial)) growths — amortized O(1)
  copy work per inserted edge. Vertex counts round up to
  block_v · tile-shards (`kernel.aligned_vertex_count`) so a grown Pallas
  tiling keeps full destination blocks and an even per-shard block split.
* **Growth = fingerprint change = clean retile**: the engine's snapshot
  fingerprint includes n and capacity, so a grown snapshot can never
  alias a cached pre-growth tiling; jit caches re-key on the new shapes
  the same way (a shape step is a retrace, never a stale executable).

`launch/serve.py --grow --capacity C` drives this; `python -m
repro.core.growth` self-tests grown-update mesh parity and the
fresh-construction contract end-to-end (run it under
XLA_FLAGS=--xla_force_host_platform_device_count=8 for real meshes).
"""
from __future__ import annotations

import dataclasses
import math

from repro.graphs import coo
from repro.graphs.coo import BatchUpdate, CapacityError
from repro.core.snapshot import Snapshot, grow_snapshot
from repro.kernels.edge_relax.kernel import aligned_vertex_count


@dataclasses.dataclass(frozen=True)
class GrowthPolicy:
    """How far to grow past a requirement, and to what alignment.

    `factor` is the geometric step (amortization: total copy work over a
    stream is a constant multiple of the final size). `block_v`/`shards`
    set the vertex-count alignment unit (the tiling invariants above) —
    pass the serving engine's values so grown and fresh tilings share
    shapes; `capacity_align` keeps edge capacities on round slot-pair
    boundaries.
    """
    factor: float = 2.0
    block_v: int = 1
    shards: int = 1
    capacity_align: int = 64

    def __post_init__(self):
        if self.factor <= 1.0:
            raise ValueError(f"growth factor must be > 1, got {self.factor}")

    def next_capacity(self, current: int, required: int) -> int:
        """Smallest aligned capacity >= required that is a geometric step."""
        target = max(required, int(math.ceil(current * self.factor)))
        return -(-target // self.capacity_align) * self.capacity_align

    def next_n(self, current: int, required: int) -> int:
        """Smallest aligned vertex count >= required (geometric step)."""
        target = max(required, int(math.ceil(current * self.factor)))
        return aligned_vertex_count(target, self.block_v, self.shards)


@dataclasses.dataclass(frozen=True)
class GrowthEvent:
    """One growth step, for reports/benches: what grew, when, why."""
    tick: int | None
    old_capacity: int
    new_capacity: int
    old_n: int
    new_n: int
    required_capacity: int
    required_n: int


def ensure_capacity(snap: Snapshot, batch: BatchUpdate,
                    policy: GrowthPolicy = GrowthPolicy(), *,
                    grow: bool = True, tick: int | None = None
                    ) -> tuple[Snapshot, GrowthEvent | None]:
    """Make `snap` big enough to absorb `batch`, growing if allowed.

    Returns (snapshot, event): the snapshot unchanged with event None
    when the batch fits; a same-version grown snapshot (plan dropped —
    re-prepare with the engine) with the event when it doesn't and
    `grow` is set. With `grow=False` an overflow raises `CapacityError`
    carrying the tick and the required sizes — the pre-growth check that
    call-sites surface instead of a shape error from deep inside jit.
    """
    g = snap.graph
    req_cap, req_n = coo.batch_requirements(g, batch)
    if req_cap <= g.capacity and req_n <= g.n:
        return snap, None
    if not grow:
        raise CapacityError(
            f"batch{f' at tick {tick}' if tick is not None else ''} needs "
            f"edge capacity {req_cap} (have {g.capacity}) and vertex count "
            f"{req_n} (have {g.n}); re-run with growth enabled (--grow) or "
            f"provision a larger --capacity",
            tick=tick, capacity=g.capacity, required_capacity=req_cap,
            n=g.n, required_n=req_n)
    new_cap = (policy.next_capacity(g.capacity, req_cap)
               if req_cap > g.capacity else g.capacity)
    new_n = policy.next_n(g.n, req_n) if req_n > g.n else g.n
    grown = grow_snapshot(snap, capacity=new_cap, n=new_n)
    event = GrowthEvent(tick=tick, old_capacity=g.capacity,
                        new_capacity=new_cap, old_n=g.n, new_n=new_n,
                        required_capacity=req_cap, required_n=req_n)
    return grown, event


# ---------------------------------------------------------------------------
# Self-test (runnable under a forced multi-device host platform)
# ---------------------------------------------------------------------------

def _selftest() -> None:
    """Grown-state parity end to end:

    1. a grown snapshot (capacity + vertex growth) updated on every
       host-mesh factorization × both backends is bit-identical to the
       unsharded jnp update of the same grown state;
    2. a ServeLoop growth run (pure-insertion `growth` scenario starting
       at a fraction of final capacity, pipelined, mesh if the device
       count allows) drops zero queries, grows at least twice, and ends
       with a labelling bit-identical to fresh construction at the final
       grown size.

        XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
            PYTHONPATH=src python -m repro.core.growth
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.graphs import generators as gen
    from repro.graphs.coo import from_edges, make_batch, to_numpy_adj
    from repro.core.batch import batchhl_update
    from repro.core.construct import build_labelling, \
        select_landmarks_by_degree
    from repro.core.engine import RelaxEngine
    from repro.core.shard import shard_batchhl_update
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import ServeConfig, ServeLoop

    n_dev = len(jax.devices())

    # --- 1: grown-update mesh parity ------------------------------------
    n, r = 120, 8
    edges = gen.random_connected(n, extra_edges=150, seed=3)
    g = from_edges(n, edges, edges.shape[0] + 4)
    landmarks = select_landmarks_by_degree(g, r)
    lab0 = build_labelling(g, landmarks)
    # A batch that outgrows both dimensions: 8 inserts (4 free pairs) and
    # two of them wire in brand-new vertices >= n.
    ups = gen.random_batch_updates(edges, n, n_ins=6, n_del=2, seed=9)
    ups += [(5, n, False), (n, n + 1, False)]
    batch = make_batch(ups, pad_to=12)
    policy = GrowthPolicy(block_v=32, shards=2)
    snap, event = ensure_capacity(Snapshot(0, g, lab0, None), batch,
                                  policy, tick=0)
    assert event is not None and snap.graph.n == policy.next_n(n, n + 2)
    assert snap.graph.capacity >= edges.shape[0] + 8

    g1, lab1, aff1 = batchhl_update(snap.graph, batch, snap.labelling)
    engine = RelaxEngine(backend="pallas", block_v=32, shards=2)
    plan1 = engine.prepare(coo.apply_batch(snap.graph, batch))
    for model in [m for m in (1, 2, 4, 8) if n_dev % m == 0]:
        mesh = make_host_mesh(model=model)
        for backend, pln in (("jnp", None), ("pallas", plan1)):
            sg1, slab1, saff1 = shard_batchhl_update(
                mesh, snap.graph, batch, snap.labelling, plan=pln)
            np.testing.assert_array_equal(np.asarray(saff1),
                                          np.asarray(aff1))
            for f in ("dist", "hub", "highway"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(slab1, f)),
                    np.asarray(getattr(lab1, f)))
            print(f"mesh (data={mesh.shape['data']}, model={model}) "
                  f"backend={backend}: grown-update bit-parity OK")

    # --- 2: serve-loop growth runs, fresh-construction contract ---------
    shards = 2 if n_dev % 2 == 0 and n_dev > 1 else 1
    mesh_kind = "host" if n_dev > 1 else "none"
    for backend in ("jnp", "pallas"):
        # BA(200, 1) seeds ~199 edges; 5 ticks x 60 pure inserts end near
        # 500 — starting capacity 224 forces two geometric growths
        # (224 -> 448 -> 896) while the pipelined stream keeps serving.
        cfg = ServeConfig(n=200, deg=1, landmarks=8, batches=5,
                          batch_size=60, scenario="growth", capacity=224,
                          grow=True, queries=24, qps=5000.0, microbatch=8,
                          pipeline=True, backend=backend, block_v=64,
                          tile_shards=2, mesh=mesh_kind, shards=shards,
                          quiet=True)
        loop = ServeLoop(cfg)
        rep = loop.run()
        assert sum(t.queries for t in rep.ticks) == cfg.batches * cfg.queries
        assert len(rep.growth) >= 2, rep.growth
        final = rep.final
        fresh_g = from_edges(final.graph.n,
                             np.asarray(loop._edge_list, np.int32),
                             final.graph.capacity)
        assert to_numpy_adj(fresh_g) == to_numpy_adj(final.graph)
        fresh_lab = build_labelling(fresh_g, final.labelling.landmarks)
        for f in ("dist", "hub", "highway"):
            np.testing.assert_array_equal(
                np.asarray(getattr(final.labelling, f)),
                np.asarray(getattr(fresh_lab, f)))
        print(f"serve growth backend={backend} (mesh={mesh_kind} "
              f"shards={shards}): {len(rep.growth)} growths, "
              f"capacity {rep.growth[0].old_capacity}->"
              f"{final.graph.capacity}, fresh-construction parity OK")
    print(f"growth selftest OK on {n_dev} device(s)")


if __name__ == "__main__":
    _selftest()
