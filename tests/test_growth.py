"""Grow-in-place capacity management (DESIGN.md §6).

Fast tests pin the primitives: typed `CapacityError` surfacing at every
pre-growth call-site (instead of a shape error from inside jit), the
grow/grow_labelling shape semantics, the policy's geometric + aligned
steps, growth forcing a clean engine retile, and grown state
round-tripping through the full-state checkpoint.

Slow tests pin the acceptance contract: a `growth`-scenario serve run
starting at 1/4 of its final capacity completes with zero dropped
queries and a post-growth labelling bit-identical to fresh construction
at the final grown size — in-process on the 1-device mesh for both
backends, and via the `python -m repro.core.growth` selftest subprocess
on a forced 8-device host platform (every mesh factorization × both
backends). The differential soak drives a 50-tick random mixed stream —
across two capacity growths and one vertex growth — checking every
tick's full distance matrix against the BFS oracle.
"""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.graphs import generators as gen
from repro.graphs.coo import (CapacityError, apply_batch,
                              batch_requirements, from_edges, grow,
                              make_batch, to_numpy_adj, INF_D)
from repro.core.batch import batchhl_update
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.engine import RelaxEngine
from repro.core.growth import GrowthPolicy, ensure_capacity
from repro.core.labelling import grow_labelling
from repro.core.query import batched_query
from repro.core.snapshot import (Snapshot, grow_snapshot, restore_snapshot,
                                 save_snapshot)
from repro.core import ref
from repro.kernels.edge_relax.kernel import aligned_vertex_count
from repro.launch.serve import ServeConfig, ServeLoop

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _instance(n=40, extra=20, seed=5, r=4, slack=2):
    edges = gen.random_connected(n, extra_edges=extra, seed=seed)
    g = from_edges(n, edges, edges.shape[0] + slack)
    landmarks = select_landmarks_by_degree(g, r)
    return edges, g, landmarks, build_labelling(g, landmarks)


def _assert_labellings_equal(a, b):
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)))


# --- typed overflow errors --------------------------------------------------

def test_from_edges_raises_capacity_error():
    edges = np.array([[0, 1], [1, 2], [2, 3]], np.int32)
    with pytest.raises(CapacityError, match="exceed capacity") as e:
        from_edges(4, edges, 2)
    assert isinstance(e.value, ValueError)  # typed, still a ValueError
    assert e.value.required_capacity == 3 and e.value.capacity == 2


def test_ensure_capacity_raises_with_tick_and_requirements():
    _, g, _, lab = _instance()
    snap = Snapshot(0, g, lab, None)
    batch = make_batch([(0, 1, True), (2, 39, False), (3, 38, False),
                        (4, 37, False), (5, 36, False)], pad_to=5)
    req_cap, req_n = batch_requirements(g, batch)
    # exact: 4 insertions minus the one pair freed by deleting edge (0, 1),
    # one more than the graph's 2 free pairs
    assert req_cap == int(jnp.sum(g.valid)) // 2 + 3 and req_n == 40
    assert req_cap == g.capacity + 1
    with pytest.raises(CapacityError, match="tick 11") as e:
        ensure_capacity(snap, batch, GrowthPolicy(), grow=False, tick=11)
    assert e.value.tick == 11
    assert e.value.required_capacity == req_cap
    assert e.value.capacity == g.capacity


def test_serve_loop_surfaces_capacity_error():
    """The serve-loop call-site raises the typed error naming the batch
    tick — before any device dispatch, not a jit shape error."""
    cfg = ServeConfig(n=60, deg=1, landmarks=4, batches=3, batch_size=30,
                      scenario="growth", capacity=64, grow=False,
                      queries=4, qps=1e6, microbatch=4, quiet=True)
    with pytest.raises(CapacityError, match="tick 0") as e:
        ServeLoop(cfg).run()
    assert e.value.tick == 0 and e.value.required_capacity > 64


def test_full_capacity_churn_batch_is_not_rejected():
    """Exactness of the requirement: at zero free pairs, a batch whose
    deletions free exactly the pairs its insertions need must pass the
    grow=False check (deletions are applied first), not be rejected by a
    deletions-blind over-count."""
    edges, g, _, lab = _instance(slack=0)        # capacity == edge count
    n = g.n
    d0 = (int(edges[0][0]), int(edges[0][1]))
    d1 = (int(edges[1][0]), int(edges[1][1]))
    have = {(min(u, v), max(u, v)) for u, v in edges}
    fresh = [(u, v) for u in range(n) for v in range(u + 1, n)
             if (u, v) not in have][:2]
    batch = make_batch([(d0[0], d0[1], True), (d1[0], d1[1], True),
                        (fresh[0][0], fresh[0][1], False),
                        (fresh[1][0], fresh[1][1], False)], pad_to=4)
    req_cap, _ = batch_requirements(g, batch)
    assert req_cap == g.capacity                 # fits exactly
    snap, event = ensure_capacity(Snapshot(0, g, lab, None), batch,
                                  GrowthPolicy(), grow=False, tick=0)
    assert event is None and snap.graph is g
    g2 = apply_batch(g, batch)
    assert to_numpy_adj(g2) == ref.apply_updates(
        to_numpy_adj(g), [(d0[0], d0[1], True), (d1[0], d1[1], True),
                          (fresh[0][0], fresh[0][1], False),
                          (fresh[1][0], fresh[1][1], False)])


def test_update_shape_guard_names_growth():
    """A grown graph with un-grown planes fails at trace time with an
    error that names the growth helpers, not a gather shape error."""
    _, g, _, lab = _instance()
    g_big = grow(g, n=48)
    batch = make_batch([(0, 1, True)], pad_to=1)
    with pytest.raises(ValueError, match="grow them together"):
        batchhl_update(g_big, batch, lab)


# --- growth primitives ------------------------------------------------------

def test_grow_preserves_graph_and_widens_labelling():
    edges, g, landmarks, lab = _instance()
    g2 = grow(g, capacity=g.capacity + 40, n=g.n + 24)
    assert g2.capacity == g.capacity + 40 and g2.n == g.n + 24
    assert to_numpy_adj(g2) == {**to_numpy_adj(g),
                                **{v: set() for v in range(g.n, g2.n)}}
    lab2 = grow_labelling(lab, g2.n)
    assert lab2.dist.shape == (4, g2.n)
    np.testing.assert_array_equal(np.asarray(lab2.dist[:, :g.n]),
                                  np.asarray(lab.dist))
    assert np.all(np.asarray(lab2.dist[:, g.n:]) == int(INF_D))
    assert not np.any(np.asarray(lab2.hub[:, g.n:]))
    # grown == fresh construction at the grown size, bit for bit
    fresh = build_labelling(g2, landmarks)
    _assert_labellings_equal(lab2, fresh)
    with pytest.raises(ValueError, match="shrink"):
        grow(g2, capacity=g.capacity)
    with pytest.raises(ValueError, match="shrink"):
        grow_labelling(lab2, g.n)


def test_growth_policy_geometric_and_aligned():
    pol = GrowthPolicy(block_v=64, shards=2, capacity_align=64)
    # geometric: at least ×2 even when the requirement barely overflows
    assert pol.next_capacity(100, 101) == 256  # ceil(200/64)*64
    # requirement dominates when it outruns the geometric step
    assert pol.next_capacity(100, 1000) == 1024
    assert pol.next_n(100, 101) == 256          # align 128: ceil(200)→256
    assert pol.next_n(100, 999) == 1024
    assert aligned_vertex_count(1, 64, 2) == 128
    assert aligned_vertex_count(128, 64, 2) == 128
    assert aligned_vertex_count(129, 64, 2) == 256
    with pytest.raises(ValueError):
        aligned_vertex_count(0, 64, 2)
    with pytest.raises(ValueError):
        GrowthPolicy(factor=1.0)


def test_ensure_capacity_grows_and_update_matches_fresh():
    """Capacity + vertex growth in one batch; post-update labelling is
    bit-identical to fresh construction at the grown size, on both
    backends through one shared engine (growth = clean retile)."""
    edges, g, landmarks, lab = _instance()
    n = g.n
    ups = gen.random_batch_updates(edges, n, n_ins=3, n_del=1, seed=7)
    ups += [(1, n, False), (n, n + 1, False)]    # two brand-new vertices
    batch = make_batch(ups, pad_to=len(ups))
    snap, event = ensure_capacity(Snapshot(0, g, lab, None), batch,
                                  GrowthPolicy(block_v=16, shards=2),
                                  tick=4)
    assert event is not None and event.tick == 4
    assert snap.version == 0                     # same version: same graph
    assert snap.graph.n == 96 and snap.graph.n % 32 == 0
    assert snap.graph.capacity >= event.required_capacity

    engine = RelaxEngine(backend="pallas", block_v=16, shards=2)
    plan_pre = engine.prepare(g)
    g_next = apply_batch(snap.graph, batch)
    plan = engine.prepare(g_next)
    assert engine.retile_count == 2              # grown fp ≠ pre-growth fp
    gj, labj, affj = batchhl_update(snap.graph, batch, snap.labelling)
    gp, labp, affp = batchhl_update(snap.graph, batch, snap.labelling,
                                    plan=plan, g_new=g_next)
    np.testing.assert_array_equal(np.asarray(affj), np.asarray(affp))
    _assert_labellings_equal(labj, labp)
    fresh_edges = np.asarray(
        sorted({(min(u, v), max(u, v))
                for u, adjs in to_numpy_adj(gj).items() for v in adjs}),
        np.int32)
    fresh = build_labelling(from_edges(gj.n, fresh_edges, gj.capacity),
                            landmarks)
    _assert_labellings_equal(labj, fresh)


def test_grown_state_checkpoint_roundtrip(tmp_path):
    """Grown shapes (capacity and n) survive save → restore bit-exactly;
    the restore is self-describing, no template needed."""
    edges, g, landmarks, lab = _instance()
    snap = grow_snapshot(Snapshot(3, g, lab, None), capacity=g.capacity * 3,
                         n=g.n + 16)
    batch = make_batch([(0, g.n + 5, False)], pad_to=1)
    g2, lab2, _ = batchhl_update(snap.graph, batch, snap.labelling)
    save_snapshot(str(tmp_path / "ck"), Snapshot(4, g2, lab2, None))
    back = restore_snapshot(str(tmp_path / "ck"))
    assert back.version == 4
    assert back.graph.capacity == g.capacity * 3
    assert back.graph.n == g.n + 16
    for f in ("src", "dst", "valid"):
        np.testing.assert_array_equal(np.asarray(getattr(back.graph, f)),
                                      np.asarray(getattr(g2, f)))
    _assert_labellings_equal(back.labelling, lab2)


def test_resume_rejects_foreign_config_checkpoint(tmp_path):
    """A grown checkpoint resumes under its own config (base_n matches),
    but a checkpoint from a different-n run is rejected even when its
    graph is large enough to 'fit' — grown n alone cannot tell the two
    apart, so the base n rides along in the checkpoint."""
    base = dict(deg=1, landmarks=4, batches=2, batch_size=40,
                scenario="growth", capacity=96, grow=True, queries=4,
                qps=1e6, microbatch=4, quiet=True)
    ck = str(tmp_path / "ck")
    rep = ServeLoop(ServeConfig(n=80, **base, ckpt_dir=ck)).run()
    assert len(rep.growth) >= 1                  # the checkpoint is grown
    # same config resumes (idempotent here: stream already finished)
    resumed = ServeLoop(ServeConfig(n=80, **base, ckpt_dir=ck,
                                    resume=True)).run()
    assert resumed.final.version == rep.final.version
    with pytest.raises(ValueError, match="n=80"):
        ServeLoop(ServeConfig(n=60, **base, ckpt_dir=ck,
                              resume=True)).run()


# --- acceptance: growth-scenario serve runs (1/4 final capacity) ------------

@pytest.mark.slow
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_growth_scenario_fresh_construction_parity(backend):
    """A `growth` run starting at 1/4 of its final capacity (128 → 512
    over two geometric growths) serves every query, keeps the pipeline's
    staleness ≤ 1, and ends bit-identical to fresh construction at the
    final grown size."""
    cfg = ServeConfig(n=120, deg=1, landmarks=8, batches=4, batch_size=45,
                      scenario="growth", capacity=128, grow=True,
                      queries=16, qps=5000.0, microbatch=8, pipeline=True,
                      backend=backend, block_v=64, tile_shards=2,
                      quiet=True)
    loop = ServeLoop(cfg)
    rep = loop.run()
    # zero dropped queries: every arrival of every tick was answered
    assert sum(t.queries for t in rep.ticks) == cfg.batches * cfg.queries
    assert all(m.staleness <= 1 for m in rep.microbatches)
    assert len(rep.growth) >= 2
    final = rep.final
    assert final.graph.capacity == 4 * 128
    fresh_g = from_edges(final.graph.n,
                         np.asarray(loop._edge_list, np.int32),
                         final.graph.capacity)
    assert to_numpy_adj(fresh_g) == to_numpy_adj(final.graph)
    fresh_lab = build_labelling(fresh_g, final.labelling.landmarks)
    _assert_labellings_equal(final.labelling, fresh_lab)


@pytest.mark.slow
def test_growth_selftest_multidevice():
    """The forced-8-device acceptance leg: grown-update bit-parity on
    every mesh factorization × both backends, plus the mesh growth serve
    runs with fresh-construction parity (python -m repro.core.growth)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.core.growth"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "growth selftest OK on 8 device(s)" in out.stdout, out.stdout


# --- differential soak: 50 ticks vs the BFS oracle --------------------------

@pytest.mark.slow
def test_differential_soak_50_ticks_with_growth():
    """50-tick random mixed stream; every tick's FULL distance matrix is
    checked against the BFS oracle, across >= 2 capacity growths and one
    vertex growth (tick 12 wires in a brand-new vertex)."""
    n0, r = 40, 4
    edges = gen.random_connected(n0, extra_edges=20, seed=5)
    g = from_edges(n0, edges, 64)              # barely above the seed edges
    landmarks = select_landmarks_by_degree(g, r)
    lab = build_labelling(g, landmarks)
    snap = Snapshot(0, g, lab, None)
    policy = GrowthPolicy(block_v=8, shards=1)
    cur = {(min(int(u), int(v)), max(int(u), int(v))) for u, v in edges}
    cap_growths = n_growths = 0
    for tick in range(50):
        cur_arr = np.asarray(sorted(cur), np.int32)
        ups = gen.random_batch_updates(cur_arr, snap.graph.n, n_ins=4,
                                       n_del=2, seed=1000 + tick)
        if tick == 12:  # vertex growth: attach a brand-new vertex id >= n
            ups.append((0, snap.graph.n, False))
        batch = make_batch(ups, pad_to=8)
        snap, event = ensure_capacity(snap, batch, policy, tick=tick)
        if event is not None:
            cap_growths += event.new_capacity > event.old_capacity
            n_growths += event.new_n > event.old_n
        g2, lab2, _ = batchhl_update(snap.graph, batch, snap.labelling)
        snap = Snapshot(snap.version + 1, g2, lab2, None)
        for u, v, is_del in ups:
            k = (min(u, v), max(u, v))
            cur.discard(k) if is_del else cur.add(k)

        nn = g2.n
        qs, qt = np.meshgrid(np.arange(nn, dtype=np.int32),
                             np.arange(nn, dtype=np.int32), indexing="ij")
        got = np.asarray(batched_query(g2, lab2, jnp.asarray(qs.ravel()),
                                       jnp.asarray(qt.ravel())),
                         np.int64).reshape(nn, nn)
        adj = to_numpy_adj(g2)
        for s in range(nn):
            d = ref.bfs_dist(adj, nn, s)
            want = np.asarray([int(INF_D) if x == ref.INF else int(x)
                               for x in d], np.int64)
            np.testing.assert_array_equal(got[s], want,
                                          err_msg=f"tick {tick} src {s}")
    assert cap_growths >= 2, cap_growths
    assert n_growths >= 1, n_growths
