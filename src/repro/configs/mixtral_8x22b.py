"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768 — 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf]."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

ARCH_ID = "mixtral-8x22b"
FAMILY = "lm"
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def model_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
        d_head=128, d_ff=16384, vocab=32768,
        attn_pattern="swa", window=4096,
        moe=True, n_experts=8, n_shared_experts=0, top_k=2,
        d_ff_expert=16384, first_k_dense=0,
        act="silu", gated=True, rope_theta=1000000.0, dtype=jnp.bfloat16)


def reduced_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_model=64, n_heads=8,
        n_kv_heads=2, d_head=8, d_ff=128, vocab=512,
        attn_pattern="swa", window=8,
        moe=True, n_experts=4, n_shared_experts=0, top_k=2, d_ff_expert=64,
        act="silu", gated=True, dtype=jnp.float32,
        q_chunk=16, kv_chunk=16, loss_chunk=16)
