"""Serving-scenario registry: named workload shapes for the serve loop.

A scenario fixes the two streams the serving pipeline is measured under
(DESIGN.md §5): the *update* stream (how much of each tick's batch is
insertions vs deletions, and whether churn arrives steadily or in
bursts) and the *query* stream (which sources the open-loop query
traffic draws). Everything else — arrival times, batch padding, seeds —
is owned by the serve loop, so scenarios stay pure workload shape and
two loops running the same scenario see bit-identical streams.

Registry (`SCENARIOS` / `get_scenario`):

  mixed         50/50 insert/delete churn, uniform query sources
  insert-heavy  90/10 — the labelling mostly tightens; tilings retile
                every tick (worst case for the plan cache)
  delete-heavy  10/90 — validity-bit churn; tilings are reused across
                ticks (best case for the plan cache)
  bursty        full-size batch every `burst_period`-th tick, a trickle
                otherwise — commit-latency spikes under a steady query
                stream (the staleness stress test)
  skewed        50/50 churn with Zipf(1.2) query sources — traffic
                concentrates on the BA network's hubs
  growth        100/0 — pure insertions, the unbounded-stream shape: the
                edge count climbs every tick (sized so batches ×
                batch_size ≈ the initial edge count doubles the graph
                over a run). Pair with `--capacity`/`--grow` to start
                below the final size and exercise grow-in-place
                (DESIGN.md §6); without --grow it is the scenario that
                deterministically raises CapacityError
  traffic       road-network churn (weighted metric, DESIGN.md §8): most
                of each tick re-weights live edges (congestion spikes and
                decays) around a sparse insert/delete trickle, and every
                `rew_only_period`-th tick is weight-change-only — zero
                slot churn, so served capacity must not shrink. Pair with
                `--graph road` so weights actually vary

`launch/serve.py --scenario <name>` drives these; `benchmarks/ticks.py`
reports the serving trajectory under them.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.graphs import generators as gen


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One workload shape: update mix per tick + query-source law."""
    name: str
    description: str
    #: fraction of each tick's update batch that is insertions
    ins_frac: float
    #: > 0: only every burst_period-th tick gets the full batch; the
    #: others get `quiet_frac` of it (rounded, min 2 updates)
    burst_period: int = 0
    quiet_frac: float = 0.1
    #: > 0: Zipf exponent for query *sources* (targets stay uniform)
    query_skew: float = 0.0
    #: fraction of each tick's batch that re-weights existing edges
    #: (weighted metric; the remainder splits by ins_frac)
    rew_frac: float = 0.0
    #: > 0: every rew_only_period-th tick (tick > 0) is weight-change
    #: only — no insertions or deletions, so no slot churn
    rew_only_period: int = 0
    #: > 1: inserts/reweights draw uniform weights in [1, max_weight]
    max_weight: int = 1

    def update_counts(self, tick: int,
                      batch_size: int) -> tuple[int, int, int]:
        """(n_ins, n_del, n_rew) for this tick's batch."""
        size = batch_size
        if self.burst_period and tick % self.burst_period:
            size = max(2, int(round(batch_size * self.quiet_frac)))
        if self.rew_only_period and tick > 0 \
                and tick % self.rew_only_period == 0:
            return 0, 0, size
        n_rew = int(round(size * self.rew_frac))
        rest = size - n_rew
        n_ins = int(round(rest * self.ins_frac))
        return n_ins, rest - n_ins, n_rew

    def max_inserts(self, ticks: int, batch_size: int) -> int:
        """Upper bound on total insertions — sizes the graph capacity."""
        return sum(self.update_counts(t, batch_size)[0]
                   for t in range(ticks))

    def sample_queries(self, rng: np.random.Generator, n: int,
                       size: int) -> tuple[np.ndarray, np.ndarray]:
        """One tick's query pairs (sources [size], targets [size])."""
        if self.query_skew > 0:
            src = gen.zipf_vertices(rng, n, size, self.query_skew)
        else:
            src = rng.integers(0, n, size).astype(np.int32)
        dst = rng.integers(0, n, size).astype(np.int32)
        return src, dst


SCENARIOS: dict[str, Scenario] = {s.name: s for s in (
    Scenario("mixed", "50/50 insert/delete churn, uniform queries",
             ins_frac=0.5),
    Scenario("insert-heavy", "90/10 churn: retile-every-tick worst case",
             ins_frac=0.9),
    Scenario("delete-heavy", "10/90 churn: tiling-reuse best case",
             ins_frac=0.1),
    Scenario("bursty", "full batch every 3rd tick, trickle otherwise",
             ins_frac=0.5, burst_period=3),
    Scenario("skewed", "50/50 churn, Zipf(1.2) hub-skewed query sources",
             ins_frac=0.5, query_skew=1.2),
    Scenario("growth", "pure insertions: the edge count climbs every tick "
                       "(grow-in-place stress; pair with --capacity/--grow)",
             ins_frac=1.0),
    Scenario("traffic", "road-network weight churn: spikes/decays on live "
                        "edges + sparse insert/delete trickle; every 4th "
                        "tick is weight-change-only (no slot churn)",
             ins_frac=0.5, rew_frac=0.75, rew_only_period=4, max_weight=8),
)}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; registry: "
            f"{', '.join(sorted(SCENARIOS))}") from None
