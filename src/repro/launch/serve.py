"""BatchHL distance-query serving driver — the paper's system end-to-end.

    PYTHONPATH=src python -m repro.launch.serve --n 2000 --batches 5

Loop per tick: ingest a batch of edge updates (insert+delete mix), run
BatchHL (batch search + batch repair), answer a query batch, report
latencies and labelling size. Optionally verifies every answer against a
BFS oracle (--verify), and checkpoints the labelling for restart.

Sweep backend: ``--backend {auto,jnp,pallas}`` selects the relaxation
engine backend (DESIGN.md §3). The loop owns one `RelaxEngine`, so the
Pallas destination-block tiling is prepared once per tick — and reused
outright across deletion-only ticks — then amortized over every wave of
batch search, batch repair, and the query-side BiBFS in that tick.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs import generators as gen
from repro.graphs.coo import apply_batch, from_edges, make_batch, to_numpy_adj
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.batch import batchhl_update
from repro.core.engine import RelaxEngine
from repro.core.query import batched_query
from repro.core import ref
from repro.checkpoint import manager as ckpt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--deg", type=int, default=4)
    ap.add_argument("--landmarks", type=int, default=16)
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=100)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "jnp", "pallas"),
                    help="relaxation-engine backend for every sweep "
                         "(auto = pallas on TPU, jnp elsewhere)")
    ap.add_argument("--block-v", type=int, default=512,
                    help="destination-block size for the pallas tiling")
    ap.add_argument("--use-minplus-kernel", action="store_true",
                    help="route the Eq.-3 upper bound through the Pallas "
                         "minplus kernel")
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    edges = gen.barabasi_albert(args.n, args.deg, seed=0)
    cap = edges.shape[0] + args.batches * args.batch_size + 64
    g = from_edges(args.n, edges, cap)
    landmarks = select_landmarks_by_degree(g, args.landmarks)

    engine = RelaxEngine(backend=args.backend, block_v=args.block_v)
    plan = engine.prepare(g)

    t0 = time.time()
    lab = build_labelling(g, landmarks, plan=plan)
    jax.block_until_ready(lab.dist)
    print(f"constructed labelling: {args.n} vertices, "
          f"{edges.shape[0]} edges, R={args.landmarks}, "
          f"size={int(lab.label_size())}, {time.time() - t0:.2f}s "
          f"[backend={engine.backend}]")

    cur_edges = edges.copy()
    rng = np.random.default_rng(7)
    for tick in range(args.batches):
        ups = gen.random_batch_updates(
            cur_edges, args.n, n_ins=args.batch_size // 2,
            n_del=args.batch_size // 2, seed=100 + tick)
        batch = make_batch(ups, pad_to=args.batch_size)
        t0 = time.time()
        # One tiling per tick, prepared from the post-update snapshot so it
        # covers inserted edges; deletion-only ticks reuse the cached tiles.
        # Counted inside the update time: it is real per-tick work on the
        # pallas backend. The jnp backend skips the snapshot entirely.
        if engine.backend == "jnp":
            plan = engine.prepare(g)
        else:
            has_ins = any(not is_del for (_, _, is_del) in ups)
            plan = engine.prepare(apply_batch(g, batch),
                                  topology_changed=has_ins)
        g, lab, aff = batchhl_update(g, batch, lab, improved=True, plan=plan)
        jax.block_until_ready(lab.dist)
        t_upd = time.time() - t0

        qs = jnp.asarray(rng.integers(0, args.n, args.queries), jnp.int32)
        qt = jnp.asarray(rng.integers(0, args.n, args.queries), jnp.int32)
        t0 = time.time()
        dist = batched_query(g, lab, qs, qt,
                             use_kernel=args.use_minplus_kernel, plan=plan)
        jax.block_until_ready(dist)
        t_q = time.time() - t0

        print(f"tick {tick}: update {t_upd * 1e3:.1f}ms "
              f"({int(jnp.sum(aff))} affected) | "
              f"{args.queries} queries {t_q * 1e3:.1f}ms "
              f"({t_q / args.queries * 1e6:.0f}us/q) | "
              f"label size {int(lab.label_size())}")

        # maintain host-side edge list for the next update generator
        adjset = {(min(a, b), max(a, b)) for a, b in cur_edges}
        for u, v, is_del in ups:
            k = (min(u, v), max(u, v))
            if is_del:
                adjset.discard(k)
            else:
                adjset.add(k)
        cur_edges = np.asarray(sorted(adjset), np.int32)

        if args.verify:
            adj = to_numpy_adj(g)
            wrong = 0
            for i in range(min(64, args.queries)):
                o = ref.pair_distance(adj, args.n, int(qs[i]), int(qt[i]))
                got = float(dist[i])
                o = got if (o == ref.INF and got >= 1e8) else o
                if int(qs[i]) == int(qt[i]):
                    o = 0
                wrong += int(got != o)
            print(f"  verify: {wrong}/64 mismatches")

        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, tick + 1,
                      {"dist": lab.dist, "hub": lab.hub,
                       "highway": lab.highway, "landmarks": lab.landmarks})
    print(f"serve loop done [backend={engine.backend}, "
          f"retiles={engine.retile_count}/{args.batches + 1} prepares]")


if __name__ == "__main__":
    main()
