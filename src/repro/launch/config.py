"""Composable serve-tier configuration: typed specs, one CLI, one JSON.

The serve tier used to be configured by a monolithic flat `ServeConfig`
plus a 30-flag argparse block in `launch/serve.py`; every new process
role (replica updater, readers, router — `launch/replica.py`) would have
re-parsed its own duplicate of those flags. This module re-cuts the
surface into five composable specs —

  * `GraphSpec`       — the graph under service (family, size, capacity,
                        grow-in-place policy)
  * `EngineSpec`      — the relaxation engine + mesh (backend, tiling,
                        autotune/fusion, shard_map axes)
  * `StreamSpec`      — the workload (update batches, scenario, open-loop
                        query stream, serving mode, verification)
  * `CheckpointSpec`  — durability (checkpoint dir, resume, prune keep)
  * `TopologySpec`    — process topology (reader count, ports, router
                        admission/coalescing, publish-barrier knobs)

— combined in `ServeSpec`, with a **lossless** round-trip through both
representations every role shares:

  * CLI:  `spec.to_args()` emits exactly the non-default flags;
          `from_parsed_args(ns)` inverts it. The parser is *built from
          the specs* (`add_spec_args`), so a flag exists in exactly one
          place.
  * JSON: `spec.to_json()` / `ServeSpec.from_json()` — the updater,
          readers, and router of one deployment are all launched from
          this single serialized document instead of flag duplicates.

The old flat `ServeConfig` (what `ServeLoop` consumes in-process)
remains as the thin legacy adapter: `spec.to_serve_config()` /
`ServeSpec.from_serve_config(cfg)` map between the two by field name.
Mixing flat override flags with `--config` on the CLI still works but
warns — the serialized spec is the source of truth for multi-process
deployments (DESIGN.md §9).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import warnings

from repro.launch.serve import ServeConfig


def _f(default, help_: str, choices: tuple | None = None, arg_type=None):
    """A dataclass field carrying its own CLI metadata."""
    meta = {"help": help_}
    if choices is not None:
        meta["choices"] = choices
    if arg_type is not None:
        meta["type"] = arg_type
    return dataclasses.field(default=default, metadata=meta)


@dataclasses.dataclass(frozen=True)
class GraphSpec:
    """The graph under service."""
    n: int = _f(2000, "vertex count (road rounds up to rows*cols)")
    deg: int = _f(4, "Barabási–Albert attachment degree")
    graph: str = _f("ba", "graph family: ba = power-law unit weights, "
                    "road = weighted planar grid", choices=("ba", "road"))
    landmarks: int = _f(16, "highway-cover landmark count R")
    capacity: int | None = _f(None, "initial edge capacity (slot pairs); "
                              "default provisions the scenario's worst case",
                              arg_type=int)
    grow: bool = _f(False, "grow slots + planes geometrically on overflow "
                    "(DESIGN.md §6); without it overflow raises "
                    "CapacityError")
    growth_factor: float = _f(2.0, "geometric growth step (> 1)")

    def realized_n(self) -> int:
        """The vertex count the loop actually serves: `road` rounds n up
        to the grid's rows·cols (the same rule `ServeLoop` applies), so
        out-of-process clients sample queries over the right range."""
        if self.graph != "road":
            return self.n
        import math
        rows = max(2, int(math.isqrt(self.n)))
        cols = max(2, (self.n + rows - 1) // rows)
        return rows * cols


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Relaxation engine + mesh placement."""
    backend: str = _f("auto", "relaxation-engine backend for every sweep",
                      choices=("auto", "jnp", "pallas"))
    block_v: int = _f(512, "destination-block size of the pallas tiling")
    tile_shards: int = _f(1, "vertex-shard count of the pallas tiling")
    block_e: int | None = _f(None, "tile-row width cap of the pallas "
                             "tiling (default: widest block)", arg_type=int)
    autotune: bool = _f(False, "measure sweep-impl candidates per snapshot "
                        "shape and adopt the fastest (DESIGN.md §7)")
    tune_table: str | None = _f(None, "on-disk tuning table path (implies "
                                "--autotune)", arg_type=str)
    fused: bool = _f(False, "pipelined chunks as fused megakernel "
                     "dispatches with donated planes (DESIGN.md §7)")
    frontier: bool = _f(False, "frontier-proportional sweeps: relax only "
                        "the tile rows the batch's change frontier touches, "
                        "falling back to full sweeps past the density "
                        "threshold (DESIGN.md §10)")
    frontier_threshold: float = _f(0.25, "masked-sweep density fallback: "
                                   "max fraction of tile rows a frontier "
                                   "wave may gather before the full sweep "
                                   "takes over (autotunable)")
    use_minplus_kernel: bool = _f(False, "Eq.-3 bound through the Pallas "
                                  "minplus kernel")
    mesh: str = _f("none", "run sharded on a device mesh",
                   choices=("none", "host"))
    shards: int = _f(1, "model-axis size of the host mesh")


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """The workload: update stream + open-loop query stream + mode."""
    batches: int = _f(5, "serving ticks (one update batch + queries each)")
    batch_size: int = _f(100, "edge updates per tick")
    scenario: str = _f("mixed", "workload shape from the registry "
                       "(data/scenarios.py)")
    queries: int = _f(256, "open-loop query arrivals per tick")
    qps: float = _f(2000.0, "Poisson arrival rate of the query stream")
    microbatch: int = _f(32, "max queries per dispatched microbatch (also "
                         "the router's coalescing target)")
    pipeline: bool = _f(False, "serve against the committed snapshot while "
                        "the update runs as bounded chunks (DESIGN.md §5)")
    chunk_sweeps: int = _f(1, "relaxation waves per pipelined dispatch")
    seed: int = _f(7, "seed of the query/arrival streams")
    verify: bool = _f(False, "check sampled answers against the Dijkstra "
                      "oracle at the version each was answered")
    quiet: bool = _f(False, "suppress per-tick logging")


@dataclasses.dataclass(frozen=True)
class CheckpointSpec:
    """Durability of the serve state."""
    ckpt_dir: str | None = _f(None, "checkpoint the full serve state each "
                              "tick (the replica tier's publish dir)",
                              arg_type=str)
    resume: bool = _f(False, "restart from the newest checkpoint in "
                      "--ckpt-dir")
    keep: int | None = _f(None, "prune all but this many steps after each "
                          "commit (the published step is never pruned); "
                          "default keeps everything", arg_type=int)


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """Process topology of the replica tier (DESIGN.md §9).

    The in-process `ServeLoop` ignores this spec entirely; it configures
    `launch/replica.py` — one updater, `readers` reader processes, and a
    router — all launched from one serialized `ServeSpec`.
    """
    readers: int = _f(2, "reader-replica process count")
    host: str = _f("127.0.0.1", "bind host of the router and readers")
    router_port: int = _f(0, "router client port (0 = pick a free port)")
    reader_port0: int = _f(0, "first reader port; reader k binds "
                           "reader_port0 + k (0 = pick free ports)")
    coalesce_ms: float = _f(2.0, "router coalescing window: wait this long "
                            "to fill a microbatch before dispatching")
    max_queue: int = _f(512, "router admission control: reject new queries "
                        "beyond this many pending")
    slo_ms: float = _f(50.0, "p99 latency SLO (the saturation bench ramps "
                       "qps until this breaks)")
    poll_ms: float = _f(25.0, "reader CURRENT-pointer poll interval")
    barrier_timeout_s: float = _f(30.0, "updater publish barrier: wait at "
                                  "most this long for live readers to ack "
                                  "the previous version")
    restart: bool = _f(False, "orchestrator restarts crashed readers from "
                       "CURRENT")


#: (attribute on ServeSpec, spec class) — parser groups in CLI order.
SPEC_GROUPS: tuple[tuple[str, type], ...] = (
    ("graph", GraphSpec),
    ("engine", EngineSpec),
    ("stream", StreamSpec),
    ("checkpoint", CheckpointSpec),
    ("topology", TopologySpec),
)


def _flag(name: str) -> str:
    return "--" + name.replace("_", "-")


def add_spec_args(parser: argparse.ArgumentParser, cls: type,
                  title: str) -> None:
    """Register one spec's fields as an argument group, defaults from the
    dataclass — the single source of truth for every flag."""
    group = parser.add_argument_group(title)
    for f in dataclasses.fields(cls):
        meta = dict(f.metadata)
        kwargs = {"help": meta.get("help", ""), "default": f.default}
        if f.type == "bool" or isinstance(f.default, bool):
            group.add_argument(_flag(f.name), action="store_true",
                               **kwargs)
            continue
        kwargs["type"] = meta.get("type") or type(f.default)
        if "choices" in meta:
            kwargs["choices"] = meta["choices"]
        group.add_argument(_flag(f.name), **kwargs)


def _spec_from_ns(cls: type, ns: argparse.Namespace):
    return cls(**{f.name: getattr(ns, f.name)
                  for f in dataclasses.fields(cls)})


def _spec_to_args(spec) -> list[str]:
    """The non-default flags of one spec — `add_spec_args`'s inverse."""
    out: list[str] = []
    for f in dataclasses.fields(spec):
        v = getattr(spec, f.name)
        if v == f.default:
            continue
        if isinstance(v, bool):
            out.append(_flag(f.name))
        else:
            out += [_flag(f.name), str(v)]
    return out


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """The whole serve tier's configuration, composable and serializable.

    One `ServeSpec` describes one deployment — in-process (`ServeLoop`
    via `to_serve_config()`) or multi-process (`launch/replica.py`: the
    updater, every reader, and the router are launched from this one
    document via `to_json()`).
    """
    graph: GraphSpec = dataclasses.field(default_factory=GraphSpec)
    engine: EngineSpec = dataclasses.field(default_factory=EngineSpec)
    stream: StreamSpec = dataclasses.field(default_factory=StreamSpec)
    checkpoint: CheckpointSpec = dataclasses.field(
        default_factory=CheckpointSpec)
    topology: TopologySpec = dataclasses.field(default_factory=TopologySpec)

    # -- CLI ----------------------------------------------------------------

    @staticmethod
    def add_args(parser: argparse.ArgumentParser) -> None:
        for attr, cls in SPEC_GROUPS:
            add_spec_args(parser, cls, attr)

    @classmethod
    def from_parsed_args(cls, ns: argparse.Namespace) -> "ServeSpec":
        return cls(**{attr: _spec_from_ns(scls, ns)
                      for attr, scls in SPEC_GROUPS})

    def to_args(self) -> list[str]:
        """Exactly the non-default flags: `parse(to_args())` round-trips
        losslessly (pinned in tests/test_replica.py)."""
        out: list[str] = []
        for attr, _ in SPEC_GROUPS:
            out += _spec_to_args(getattr(self, attr))
        return out

    # -- JSON ---------------------------------------------------------------

    def to_json(self) -> str:
        return json.dumps({attr: dataclasses.asdict(getattr(self, attr))
                           for attr, _ in SPEC_GROUPS}, indent=2)

    @classmethod
    def from_json(cls, doc: str) -> "ServeSpec":
        raw = json.loads(doc)
        unknown = set(raw) - {attr for attr, _ in SPEC_GROUPS}
        if unknown:
            raise ValueError(f"unknown config sections {sorted(unknown)}")
        return cls(**{attr: scls(**raw.get(attr, {}))
                      for attr, scls in SPEC_GROUPS})

    def save_json(self, path: str) -> str:
        with open(path, "w") as fh:
            fh.write(self.to_json() + "\n")
        return path

    @classmethod
    def load_json(cls, path: str) -> "ServeSpec":
        with open(path) as fh:
            return cls.from_json(fh.read())

    # -- flat-ServeConfig adapter (legacy surface) --------------------------

    def to_serve_config(self, **overrides) -> ServeConfig:
        """The flat in-process form `ServeLoop` consumes.

        Field names map 1:1; `TopologySpec` and `CheckpointSpec.keep`
        have no flat counterpart (they configure processes around the
        loop, not the loop itself).
        """
        flat_names = {f.name for f in dataclasses.fields(ServeConfig)}
        flat: dict = {}
        for attr, _ in SPEC_GROUPS:
            for f in dataclasses.fields(getattr(self, attr)):
                if f.name in flat_names:
                    flat[f.name] = getattr(getattr(self, attr), f.name)
        flat.update(overrides)
        return ServeConfig(**flat)

    @classmethod
    def from_serve_config(cls, cfg: ServeConfig,
                          topology: TopologySpec | None = None
                          ) -> "ServeSpec":
        """Lift a flat legacy config into specs (by field name)."""
        specs = {}
        for attr, scls in SPEC_GROUPS:
            if scls is TopologySpec:
                continue
            kwargs = {f.name: getattr(cfg, f.name)
                      for f in dataclasses.fields(scls)
                      if hasattr(cfg, f.name)}
            specs[attr] = scls(**kwargs)
        specs["topology"] = topology or TopologySpec()
        return cls(**specs)


def build_parser(description: str, config_flag: bool = True
                 ) -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(description=description)
    if config_flag:
        parser.add_argument(
            "--config", default=None, metavar="PATH",
            help="serialized ServeSpec JSON — the canonical way to launch "
                 "any serve-tier role; flat flags given alongside it "
                 "override individual fields (deprecated, warns)")
    ServeSpec.add_args(parser)
    return parser


def spec_from_cli(ns: argparse.Namespace,
                  parser: argparse.ArgumentParser) -> ServeSpec:
    """Resolve the CLI into one `ServeSpec`.

    Without ``--config`` the flat flags simply *are* the spec. With it,
    the JSON document is the source of truth and any flat flag that was
    explicitly set to a non-default value overrides its field — the
    deprecated mixed mode, kept so existing wrappers don't break, with a
    warning naming each overridden field.
    """
    flags = ServeSpec.from_parsed_args(ns)
    if getattr(ns, "config", None) is None:
        return flags
    spec = ServeSpec.load_json(ns.config)
    merged = {}
    overridden = []
    for attr, scls in SPEC_GROUPS:
        base, over = getattr(spec, attr), getattr(flags, attr)
        fields = {}
        for f in dataclasses.fields(scls):
            v = getattr(over, f.name)
            if v != f.default and v != getattr(base, f.name):
                fields[f.name] = v
                overridden.append(f.name)
        merged[attr] = dataclasses.replace(base, **fields) if fields \
            else base
    if overridden:
        warnings.warn(
            f"flat flags {overridden} override --config fields; flat "
            f"overrides alongside --config are deprecated — edit the "
            f"serialized spec instead", DeprecationWarning, stacklevel=2)
    return ServeSpec(**merged)
