"""Checkpointing with atomic rename + elastic re-shard on restore.

Fault-tolerance contract (DESIGN.md §4):
  * save(step) writes every leaf as .npy under a temp dir, then atomically
    renames to step_<n> — a preempted writer never corrupts the latest
    checkpoint;
  * restore() finds the newest complete checkpoint and places each leaf
    with the *current* mesh/sharding — restoring a 512-chip checkpoint onto
    256 chips (or CPU) re-shards transparently (elastic scaling);
  * the data pipeline is stateless-seeded, so (params, opt, step) is the
    entire job state and restart is exact.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return leaves, treedef


def _key_str(path) -> str:
    return "__".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)


def save(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _flatten(tree)
    manifest = []
    for path, leaf in leaves:
        name = _key_str(path)
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest.append(name)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like, shardings=None, step: int | None = None):
    """Restore into the structure of `tree_like`; optionally place each
    leaf with `shardings` (same pytree structure) — elastic re-shard."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    leaves, treedef = _flatten(tree_like)
    sh_leaves = None
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "addressable_devices"))
    out = []
    for i, (path, leaf) in enumerate(leaves):
        arr = np.load(os.path.join(d, _key_str(path) + ".npy"))
        if sh_leaves is not None:
            out.append(jax.device_put(arr, sh_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree_like), out), step


def prune(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
