"""Shared benchmark plumbing: datasets, timing, CSV emission.

Datasets are synthetic power-law (Barabási–Albert) and random graphs —
the same small-diameter complex-network regime as the paper's Table 2
corpus, scaled to this CPU container. Every benchmark prints
``name,us_per_call,derived`` rows (benchmarks/run.py contract).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs import generators as gen
from repro.graphs.coo import from_edges
from repro.core.construct import build_labelling, select_landmarks_by_degree

#: (n, attachment degree) of the BA datasets; the single source of truth
#: shared with callers that regenerate the graph themselves (the serve
#: loop benches in `benchmarks/ticks.py`) so both trajectories measure
#: the same graph under the same dataset name.
BA_PARAMS = {
    "ba_2k": (2_000, 3),
    "ba_10k": (10_000, 4),
    "ba_20k": (20_000, 5),
}

#: (n, max_weight) of the weighted road-grid datasets (DESIGN.md §8);
#: the realized vertex count is the grid's rows·cols >= n.
ROAD_PARAMS = {
    "road_2k": (2_025, 8),
}

DATASETS = {
    # name: (builder, kwargs)  — ordered small → large
    "ba_2k": lambda: gen.barabasi_albert(*BA_PARAMS["ba_2k"], seed=0),
    "ba_10k": lambda: gen.barabasi_albert(*BA_PARAMS["ba_10k"], seed=1),
    "ba_20k": lambda: gen.barabasi_albert(*BA_PARAMS["ba_20k"], seed=2),
    "er_5k": lambda: gen.erdos_renyi(5_000, 0.0015, seed=3),
    # weighted planar road grid, edges [E, 3] = (u, v, w)
    "road_2k": lambda: gen.road_grid(*ROAD_PARAMS["road_2k"], seed=0),
}


@dataclass
class Instance:
    name: str
    n: int
    edges: np.ndarray
    g: object
    landmarks: object
    lab: object
    construct_s: float


_CACHE: dict[tuple, Instance] = {}


def build_instance(name: str, n_landmarks: int = 16,
                   extra_capacity: int = 4096) -> Instance:
    key = (name, n_landmarks)
    if key in _CACHE:
        return _CACHE[key]
    edges = DATASETS[name]()
    n = int(edges[:, :2].max()) + 1
    g = from_edges(n, edges, edges.shape[0] + extra_capacity)
    landmarks = select_landmarks_by_degree(g, n_landmarks)
    t0 = time.time()
    lab = build_labelling(g, landmarks)
    jax.block_until_ready(lab.dist)
    inst = Instance(name, n, edges, g, landmarks, lab, time.time() - t0)
    _CACHE[key] = inst
    return inst


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.time()
        jax.block_until_ready(fn(*args))
        ts.append(time.time() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = "") -> str:
    row = f"{name},{seconds * 1e6:.1f},{derived}"
    print(row)
    return row


def update_stream(edges: np.ndarray, n: int, batch_size: int, mode: str,
                  seed: int = 0):
    """Paper's test-data generation: decremental / incremental / mixed."""
    if mode == "decremental":
        return gen.random_batch_updates(edges, n, 0, batch_size, seed=seed)
    if mode == "incremental":
        return gen.random_batch_updates(edges, n, batch_size, 0, seed=seed)
    return gen.random_batch_updates(edges, n, batch_size // 2,
                                    batch_size - batch_size // 2, seed=seed)
