"""Padded COO graph structures for batch-dynamic graphs on TPU.

Shapes are static: a graph owns a fixed edge *capacity*; edges live in slots
with a validity mask. Batch updates toggle validity (deletions) and fill free
slots (insertions), so a single compiled executable serves every batch.

Undirected edges are stored as both directions in adjacent slot pairs
(slot 2k holds u->v, slot 2k+1 holds v->u), which keeps insertion/deletion
of the two directions in lockstep.

The metric is weighted (DESIGN.md §8): every slot carries a non-negative
int32 weight in `Graph.w`, kept in lockstep with src/dst/valid by
`from_edges`/`apply_batch`/`grow`. Real edges have weight in [1, INF_D];
free/padding slots carry 0 (never read — sweeps mask them out). The
unweighted metric is exactly the `w ≡ 1` special case. Batches support a
third op besides insert/delete: *re-weight* (`OP_REW`), which updates the
weight of an existing edge in place — no slot churn, no capacity use.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Large-but-safe int32 infinity for distances (headroom for +w relaxations).
INF_D = jnp.int32(1 << 28)

# Batch-update op codes (make_batch third tuple element; a bool is_del from
# the legacy 3-tuple format maps onto OP_INS/OP_DEL unchanged).
OP_INS, OP_DEL, OP_REW = 0, 1, 2


class CapacityError(ValueError):
    """A graph's static slots cannot hold the requested edges/vertices.

    Raised by `from_edges` at build time and by the pre-growth check of
    `core/growth.ensure_capacity` *before* any device dispatch — the
    alternative is `apply_batch` silently clobbering its last free slot
    pair, surfacing later as a wrong answer or a shape error from deep
    inside jit. Carries the numbers a caller needs to grow (or to size a
    fresh build): the tick that overflowed (None outside a serve stream),
    the current and required edge capacities (slot pairs), and the current
    and required vertex counts.
    """

    def __init__(self, message: str, *, tick: int | None = None,
                 capacity: int | None = None,
                 required_capacity: int | None = None,
                 n: int | None = None, required_n: int | None = None):
        super().__init__(message)
        self.tick = tick
        self.capacity = capacity
        self.required_capacity = required_capacity
        self.n = n
        self.required_n = required_n


@partial(jax.tree_util.register_dataclass,
         data_fields=("src", "dst", "valid", "w"), meta_fields=("n",))
@dataclasses.dataclass(frozen=True)
class Graph:
    """Padded undirected graph in COO form (both directions stored)."""
    src: jax.Array   # int32[2*cap]
    dst: jax.Array   # int32[2*cap]
    valid: jax.Array # bool[2*cap]
    w: jax.Array     # int32[2*cap] edge weight; 0 on free/padding slots
    n: int           # static vertex count

    @property
    def capacity(self) -> int:
        return self.src.shape[0] // 2

    def num_edges(self) -> jax.Array:
        return jnp.sum(self.valid) // 2


@partial(jax.tree_util.register_dataclass,
         data_fields=("src", "dst", "is_del", "valid", "w", "is_rew"),
         meta_fields=())
@dataclasses.dataclass(frozen=True)
class BatchUpdate:
    """A padded batch of edge updates (insert / delete / re-weight)."""
    src: jax.Array    # int32[U]
    dst: jax.Array    # int32[U]
    is_del: jax.Array # bool[U]
    valid: jax.Array  # bool[U]  (padding mask)
    w: jax.Array      # int32[U] weight (insert: new edge's; rew: new value)
    is_rew: jax.Array # bool[U]  re-weight op (neither insert nor delete)


def from_edges(n: int, edges: np.ndarray, capacity: int) -> Graph:
    """Build a padded Graph from a numpy edge array (undirected).

    `edges` is [m, 2] (unit weights) or [m, 3] with an int weight column.
    """
    edges = np.asarray(edges, dtype=np.int32)
    edges = edges.reshape(-1, 2) if (edges.ndim < 2 or edges.shape[1] == 2) \
        else edges.reshape(-1, 3)
    m = edges.shape[0]
    if m > capacity:
        raise CapacityError(f"{m} edges exceed capacity {capacity}",
                            capacity=capacity, required_capacity=m, n=n)
    src = np.zeros(2 * capacity, np.int32)
    dst = np.zeros(2 * capacity, np.int32)
    valid = np.zeros(2 * capacity, bool)
    w = np.zeros(2 * capacity, np.int32)
    src[0:2 * m:2], dst[0:2 * m:2] = edges[:, 0], edges[:, 1]
    src[1:2 * m:2], dst[1:2 * m:2] = edges[:, 1], edges[:, 0]
    ew = edges[:, 2] if edges.shape[1] == 3 else np.ones(m, np.int32)
    w[0:2 * m:2] = ew
    w[1:2 * m:2] = ew
    valid[:2 * m] = True
    return Graph(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(valid),
                 jnp.asarray(w), n)


def grow(g: Graph, *, capacity: int | None = None,
         n: int | None = None) -> Graph:
    """Return `g` with larger static slots: the same edge set, more room.

    New edge slots are free (valid False, src/dst zeroed — the same
    convention `from_edges` uses for its padding), and a larger `n` only
    widens the vertex id space; no existing slot moves, so the grown graph
    is the *same* graph. Shrinking is refused: slots past the new capacity
    could hold live edges, and vertex ids past the new n could be
    referenced by them.
    """
    capacity = g.capacity if capacity is None else capacity
    n = g.n if n is None else n
    if capacity < g.capacity or n < g.n:
        raise ValueError(
            f"grow cannot shrink: capacity {g.capacity}->{capacity}, "
            f"n {g.n}->{n}")
    pad = 2 * (capacity - g.capacity)
    if pad == 0:
        return Graph(g.src, g.dst, g.valid, g.w, n)
    return Graph(jnp.concatenate([g.src, jnp.zeros((pad,), jnp.int32)]),
                 jnp.concatenate([g.dst, jnp.zeros((pad,), jnp.int32)]),
                 jnp.concatenate([g.valid, jnp.zeros((pad,), bool)]),
                 jnp.concatenate([g.w, jnp.zeros((pad,), jnp.int32)]), n)


def batch_requirements(g: Graph, b: BatchUpdate) -> tuple[int, int]:
    """Host-side (required_capacity, required_n) to apply `b` to `g`.

    `required_capacity` is exact for `apply_batch`'s semantics: occupied
    slot pairs, minus the pairs the batch's own deletions free (deletions
    are processed before insertions, and the deletion match below is the
    same undirected canonical-endpoint match `apply_batch` uses — so a
    batch is rejected/grown-for iff it genuinely would not fit), plus the
    batch's valid insertions. `required_n` is one past the largest vertex
    id any valid update row touches. Costs one O(E·U) device compare +
    two scalar syncs per call — negligible next to the update it gates.
    """
    is_del = np.asarray(b.is_del)
    is_rew = np.asarray(b.is_rew)
    valid = np.asarray(b.valid)
    # Re-weights update a live slot in place — they consume no capacity.
    n_ins = int(((~is_del) & (~is_rew) & valid).sum())
    occupied_pairs = int(jnp.sum(g.valid)) // 2
    del_mask_u = b.is_del & b.valid
    g_lo = jnp.minimum(g.src, g.dst)
    g_hi = jnp.maximum(g.src, g.dst)
    b_lo = jnp.where(del_mask_u, jnp.minimum(b.src, b.dst), -1)
    b_hi = jnp.where(del_mask_u, jnp.maximum(b.src, b.dst), -1)
    hit = jnp.any((g_lo[:, None] == b_lo[None, :])
                  & (g_hi[:, None] == b_hi[None, :]), axis=1) & g.valid
    freed_pairs = int(jnp.sum(hit)) // 2
    ids = np.concatenate([np.asarray(b.src)[valid], np.asarray(b.dst)[valid]])
    required_n = int(ids.max()) + 1 if ids.size else 0
    return occupied_pairs - freed_pairs + n_ins, required_n


def make_batch(updates, pad_to: int | None = None) -> BatchUpdate:
    """updates: iterable of (u, v, op) or (u, v, op, weight).

    `op` is OP_INS/OP_DEL/OP_REW (a bool is_del from the legacy 3-tuple
    format coerces to OP_DEL/OP_INS). `weight` defaults to 1; it is the
    inserted edge's weight for OP_INS and the new value for OP_REW
    (ignored for OP_DEL). Pads to `pad_to` slots.
    """
    ups = list(updates)
    u_count = len(ups)
    size = pad_to or max(u_count, 1)
    src = np.zeros(size, np.int32)
    dst = np.zeros(size, np.int32)
    is_del = np.zeros(size, bool)
    valid = np.zeros(size, bool)
    w = np.ones(size, np.int32)
    is_rew = np.zeros(size, bool)
    for i, up in enumerate(ups):
        a, b, op = up[0], up[1], int(up[2])
        src[i], dst[i], valid[i] = a, b, True
        is_del[i] = op == OP_DEL
        is_rew[i] = op == OP_REW
        if len(up) > 3:
            w[i] = int(up[3])
    return BatchUpdate(jnp.asarray(src), jnp.asarray(dst),
                       jnp.asarray(is_del), jnp.asarray(valid),
                       jnp.asarray(w), jnp.asarray(is_rew))


@jax.jit
def apply_batch(g: Graph, b: BatchUpdate) -> Graph:
    """Apply a batch update, returning G'.

    Deletions: clear validity of matching slots (both directions).
    Re-weights: set the weight of matching live slots in place (no slot
    churn — a re-weight of a non-edge is a no-op, like an unmatched
    deletion).
    Insertions: write both directions (src/dst/weight) into the first
    free slot pair.
    Invalid (padded) updates are ignored.

    Jitted: the body is ~25 elementwise/scatter ops, and un-fused their
    per-op dispatch cost (~15ms on a 1-core host) dwarfs the actual work
    for small batches — it was the floor under every small-footprint
    tick. One compile per (capacity, batch-pad) shape pair.
    """
    # --- deletions ---------------------------------------------------------
    # Undirected match on canonical (min, max) endpoints; [E2, U] compare.
    del_mask_u = b.is_del & b.valid
    g_lo = jnp.minimum(g.src, g.dst)
    g_hi = jnp.maximum(g.src, g.dst)
    b_lo = jnp.where(del_mask_u, jnp.minimum(b.src, b.dst), -1)
    b_hi = jnp.where(del_mask_u, jnp.maximum(b.src, b.dst), -1)
    hit = jnp.any((g_lo[:, None] == b_lo[None, :])
                  & (g_hi[:, None] == b_hi[None, :]), axis=1)
    valid = g.valid & ~hit
    # Freed slots drop their weight with their validity, so a graph's slot
    # arrays are a pure function of its update history (split-batch
    # reproducibility), never of stale weights.
    w = jnp.where(hit, 0, g.w)

    # --- re-weights --------------------------------------------------------
    # Same canonical-endpoint match against the *pre-insertion* slots,
    # gated on post-deletion validity: a re-weight targets an edge that is
    # live in G after this batch's deletions, and both direction slots of
    # the pair update together.
    rew_mask_u = b.is_rew & b.valid
    r_lo = jnp.where(rew_mask_u, jnp.minimum(b.src, b.dst), -1)
    r_hi = jnp.where(rew_mask_u, jnp.maximum(b.src, b.dst), -1)
    rhit = ((g_lo[:, None] == r_lo[None, :])
            & (g_hi[:, None] == r_hi[None, :]))             # [E2, U]
    rrow = jnp.argmax(rhit, axis=1)                          # first match
    rany = jnp.any(rhit, axis=1) & valid
    w = jnp.where(rany, b.w[rrow], w)

    # --- insertions --------------------------------------------------------
    ins_mask = (~b.is_del) & (~b.is_rew) & b.valid
    u_slots = b.src.shape[0]
    # Free slot *pairs* (even index free & odd index free).
    pair_free = ~(valid[0::2] | valid[1::2])
    # Rank of each insertion among valid insertions.
    ins_rank = jnp.cumsum(ins_mask) - 1
    # The k-th free pair index, for k = 0..U-1.
    free_pair_idx = jnp.nonzero(pair_free, size=u_slots,
                                fill_value=pair_free.shape[0] - 1)[0]
    pair_for_ins = free_pair_idx[jnp.clip(ins_rank, 0, u_slots - 1)]
    even = 2 * pair_for_ins
    odd = even + 1
    # Non-insert rows scatter to an out-of-bounds index, which JAX drops —
    # never to slot 0, where duplicate writes would clobber real inserts.
    oob = jnp.int32(g.src.shape[0])
    safe_even = jnp.where(ins_mask, even, oob)
    safe_odd = jnp.where(ins_mask, odd, oob)
    src = g.src.at[safe_even].set(b.src, mode="drop")
    dst = g.dst.at[safe_even].set(b.dst, mode="drop")
    src = src.at[safe_odd].set(b.dst, mode="drop")
    dst = dst.at[safe_odd].set(b.src, mode="drop")
    valid = valid.at[safe_even].set(True, mode="drop")
    valid = valid.at[safe_odd].set(True, mode="drop")
    w = w.at[safe_even].set(b.w, mode="drop")
    w = w.at[safe_odd].set(b.w, mode="drop")
    return Graph(src, dst, valid, w, g.n)


def resolve_seed_weights(g_old: Graph, b: BatchUpdate) -> BatchUpdate:
    """Replace `b.w` with the *seed* weight of each row against G (pre-update).

    The BatchHL searches seed affected sets from the changed edge's weight
    (DESIGN.md §8): for an insertion that is the new edge's weight; for a
    deletion it is the removed edge's weight *in G* (the distances that may
    have used it); for a re-weight it is min(old, new) — the smaller weight
    seeds a smaller key, which marks a superset of the vertices affected by
    either direction of the change (repair then recomputes exactly).
    Jax-traceable; one [U, E2] canonical-endpoint compare, the same cost as
    `apply_batch`'s deletion match. Rows are left untouched for padding,
    and unmatched delete/re-weight rows fall back to weight 1 (they are
    no-ops in `apply_batch` anyway).
    """
    need_old = (b.is_del | b.is_rew) & b.valid
    g_lo = jnp.minimum(g_old.src, g_old.dst)
    g_hi = jnp.maximum(g_old.src, g_old.dst)
    b_lo = jnp.where(need_old, jnp.minimum(b.src, b.dst), -1)
    b_hi = jnp.where(need_old, jnp.maximum(b.src, b.dst), -1)
    m = ((b_lo[:, None] == g_lo[None, :])
         & (b_hi[:, None] == g_hi[None, :])
         & g_old.valid[None, :])                              # [U, E2]
    w_old = jnp.max(jnp.where(m, g_old.w[None, :], 0), axis=1)
    w_old = jnp.where(w_old == 0, 1, w_old)                   # unmatched
    w_eff = jnp.where(b.is_del, w_old,
                      jnp.where(b.is_rew, jnp.minimum(w_old, b.w), b.w))
    return dataclasses.replace(b, w=jnp.where(b.valid, w_eff, 1)
                               .astype(jnp.int32))


def to_numpy_adj(g: Graph) -> dict[int, set[int]]:
    """Adjacency dict for the oracle / tests (host only)."""
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    valid = np.asarray(g.valid)
    adj: dict[int, set[int]] = {v: set() for v in range(g.n)}
    for s, d, ok in zip(src, dst, valid):
        if ok:
            adj[int(s)].add(int(d))
    return adj


def to_numpy_wadj(g: Graph) -> dict[int, dict[int, int]]:
    """Weighted adjacency dict {u: {v: w}} for the Dijkstra oracle (host).

    Parallel slots for the same arc (should not occur via `apply_batch`,
    which deduplicates by canonical endpoints) keep the minimum weight.
    """
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    valid = np.asarray(g.valid)
    w = np.asarray(g.w)
    adj: dict[int, dict[int, int]] = {v: {} for v in range(g.n)}
    for s, d, ok, wi in zip(src, dst, valid, w):
        if ok:
            row = adj[int(s)]
            d = int(d)
            row[d] = min(row[d], int(wi)) if d in row else int(wi)
    return adj
