"""Weighted-metric satellites (DESIGN.md §8).

Pins the contracts the weighted refactor added on top of the hop-count
path:

  * saturating relaxation — a plane sitting near INF_D relaxed through a
    maximum-weight edge clamps at the sweep inf on every impl (jnp /
    sorted / pallas) instead of wrapping negative in int32;
  * weighted kernel parity — the three sweep impls agree bit-for-bit on
    weighted graphs, and with w ≡ 1 each equals its legacy unweighted
    call bit-for-bit (the w ≡ 1 regression pin);
  * checkpoint format versioning — the weight column round-trips through
    save/restore, and a pre-weighted checkpoint (no graph_w) is rejected
    with the *named* UnweightedCheckpointError, not a shape error;
  * the traffic serving scenario — served distances on the road grid
    match the Dijkstra oracle at every tick, and the weight-change-only
    ticks leave the slot arrays' validity untouched (re-weights consume
    no capacity).
"""
from __future__ import annotations

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.graphs import generators as gen
from repro.graphs.coo import (INF_D, apply_batch, from_edges, make_batch,
                              to_numpy_wadj)
from repro.kernels.edge_relax import ops as er_ops
from repro.kernels.edge_relax.ref import edge_relax
from repro.core.batch import batchhl_update
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.engine import RelaxEngine
from repro.core.snapshot import (Snapshot, UnweightedCheckpointError,
                                 restore_snapshot, save_snapshot)
from repro.launch.serve import ServeConfig, ServeLoop

INF32 = 1 << 29


@pytest.fixture(autouse=True, scope="module")
def _fresh_compile_caches():
    """This module runs at the tail of the suite, on top of a few hundred
    accumulated XLA executables; drop them first so its dispatches compile
    from a fresh client (the re-compiles it pays for are all tiny)."""
    jax.clear_caches()
    yield


def _sweep_all_impls(keys, src, dst, keep, mask, n, step, w):
    """(jnp, sorted, pallas) outputs of the same weighted sweep."""
    keys_j = jnp.asarray(keys)
    mask_j = jnp.asarray(mask)
    w_full = jnp.asarray(w)
    out_jnp = edge_relax(keys_j, jnp.asarray(src), jnp.asarray(dst),
                         mask_j, step, n, w=w_full)
    sg = er_ops.prepare_sorted(src, dst, keep, n)
    out_sorted = er_ops.relax_sweep_sorted(keys_j, sg, mask_j, step, INF32,
                                           w=w_full)
    bg = er_ops.prepare_topology(src, dst, keep, n, block_v=8)
    out_pallas = er_ops.relax_sweep(keys_j, bg, mask_j, step, INF32,
                                    w=w_full)
    return out_jnp, out_sorted, out_pallas


@pytest.mark.parametrize("step", (1, 2, 4))
def test_saturating_relaxation_near_inf(step):
    """Relax a near-INF_D plane through maximum-weight (INF_D) edges:
    step · w reaches 2^30 and key + step · w overflows int32 for step 4 —
    every impl must clamp at inf, never go negative, and stay in
    bit-parity doing so."""
    n = 6
    src = np.array([0, 1, 2, 3], np.int32)
    dst = np.array([1, 2, 3, 4], np.int32)
    keep = np.ones(4, bool)
    w = np.full(4, INF_D, np.int32)
    # The plane's own INF_KEY for this step (2·INF_D+1 for key2, …):
    # key + step·w reaches 2·step·INF_D ≈ 2^31 at step 4 — a real int32
    # wrap without the guard.
    keys = np.full(n, step * INF_D + step - 1, np.int32)
    outs = _sweep_all_impls(keys, src, dst, keep, keep, n, step, w)
    for out in outs:
        arr = np.asarray(out)
        assert (arr >= 0).all(), arr
        assert (arr <= INF32).all(), arr
        assert arr[1] == INF32  # 0→1 relax saturated, not wrapped
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[2]))


def test_weighted_sweep_parity_and_unit_weight_pin():
    """On a random weighted graph the three impls agree bit-for-bit; with
    w ≡ 1 each equals its legacy unweighted (w=None) call bit-for-bit."""
    rng = np.random.default_rng(5)
    n, m = 40, 160
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    keep = rng.random(m) < 0.8
    mask = keep & (rng.random(m) < 0.9)
    keys = rng.integers(0, 4 * n, n).astype(np.int32)
    w = rng.integers(1, 9, m).astype(np.int32)
    a, b, c = _sweep_all_impls(keys, src, dst, keep, mask, n, 2, w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    ones = np.ones(m, np.int32)
    w1 = _sweep_all_impls(keys, src, dst, keep, mask, n, 2, ones)
    legacy_jnp = edge_relax(jnp.asarray(keys), jnp.asarray(src),
                            jnp.asarray(dst), jnp.asarray(mask), 2, n)
    sg = er_ops.prepare_sorted(src, dst, keep, n)
    legacy_sorted = er_ops.relax_sweep_sorted(jnp.asarray(keys), sg,
                                              jnp.asarray(mask), 2, INF32)
    bg = er_ops.prepare_topology(src, dst, keep, n, block_v=8)
    legacy_pallas = er_ops.relax_sweep(jnp.asarray(keys), bg,
                                       jnp.asarray(mask), 2, INF32)
    for got, legacy in zip(w1, (legacy_jnp, legacy_sorted, legacy_pallas)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(legacy))


def _weighted_instance(n=30, seed=2, max_w=7):
    edges = gen.random_connected(n, extra_edges=n // 2, seed=seed)
    rng = np.random.default_rng(seed + 1)
    w = rng.integers(1, max_w + 1, size=edges.shape[0])
    ew = np.concatenate([edges, w[:, None]], axis=1).astype(np.int32)
    g = from_edges(n, ew, edges.shape[0] + 8)
    landmarks = select_landmarks_by_degree(g, 4)
    lab = build_labelling(g, landmarks)
    return g, lab, ew


def test_weighted_update_parity_across_backends():
    """A mixed insert/delete/re-weight batch updates to bit-identical
    labellings on the jnp and pallas backends, equal to fresh
    construction on the post-update graph."""
    g, lab, ew = _weighted_instance()
    ups = gen.random_batch_updates(ew, g.n, n_ins=2, n_del=1, seed=3,
                                   n_rew=2, max_weight=6)
    assert any(int(u[2]) == 2 for u in ups)  # the batch does re-weight
    batch = make_batch(ups, pad_to=8)
    results = []
    for backend in ("jnp", "pallas"):
        engine = None if backend == "jnp" else RelaxEngine(
            backend="pallas", block_v=16)
        g_next = apply_batch(g, batch)
        plan = engine.prepare(g_next) if engine else None
        g2, lab2, _ = batchhl_update(g, batch, lab, improved=True,
                                     plan=plan, g_new=g_next)
        results.append((g2, lab2))
    fresh = build_labelling(results[0][0], lab.landmarks)
    for g2, lab2 in results:
        assert to_numpy_wadj(g2) == to_numpy_wadj(results[0][0])
        for f in ("dist", "hub", "highway"):
            np.testing.assert_array_equal(np.asarray(getattr(lab2, f)),
                                          np.asarray(getattr(fresh, f)))


# --- checkpoint format versioning ------------------------------------------

def test_checkpoint_roundtrips_weight_column(tmp_path):
    g, lab, _ = _weighted_instance()
    save_snapshot(str(tmp_path / "ck"), Snapshot(3, g, lab, None))
    back = restore_snapshot(str(tmp_path / "ck"))
    assert back.version == 3
    np.testing.assert_array_equal(np.asarray(back.graph.w),
                                  np.asarray(g.w))
    np.testing.assert_array_equal(np.asarray(back.graph.valid),
                                  np.asarray(g.valid))


def test_pre_weighted_checkpoint_rejected_by_name(tmp_path):
    """Deleting graph_w simulates a checkpoint written before the
    weighted-metric format: restore must raise the named error, not a
    downstream shape/KeyError."""
    g, lab, _ = _weighted_instance()
    save_snapshot(str(tmp_path / "ck"), Snapshot(1, g, lab, None))
    step_dirs = [d for d in os.listdir(tmp_path / "ck")
                 if d.startswith("step_")]
    assert step_dirs
    os.remove(tmp_path / "ck" / step_dirs[0] / "graph_w.npy")
    with pytest.raises(UnweightedCheckpointError,
                       match="weighted-metric format"):
        restore_snapshot(str(tmp_path / "ck"))
    # And the named error is still a FileNotFoundError, so pre-existing
    # callers that handled missing state keep working.
    assert issubclass(UnweightedCheckpointError, FileNotFoundError)


# --- the traffic serving scenario ------------------------------------------

def test_traffic_serve_dijkstra_exact_and_slotless_reweights():
    """Five traffic ticks on the road grid, verified: every sampled
    answer matches the Dijkstra oracle at its version, and the
    weight-change-only tick (tick 4) leaves the slot validity untouched
    — re-weights consume no capacity."""
    cfg = ServeConfig(n=49, graph="road", scenario="traffic", landmarks=6,
                      batches=5, batch_size=10, queries=16, qps=5000.0,
                      microbatch=8, verify=True, quiet=True,
                      keep_history=True)
    loop = ServeLoop(cfg)
    assert cfg.n == 49  # 7x7 grid realized exactly
    rep = loop.run()
    assert rep.final.version == 5
    assert all((t.verify_mismatches or 0) == 0 for t in rep.ticks)
    # tick 4 is the scenario's weight-change-only tick: same validity
    # plane before and after, weights the only thing that moved.
    g_before = rep.history[4].graph
    g_after = rep.history[5].graph
    np.testing.assert_array_equal(np.asarray(g_before.valid),
                                  np.asarray(g_after.valid))
    np.testing.assert_array_equal(np.asarray(g_before.src),
                                  np.asarray(g_after.src))
    assert not np.array_equal(np.asarray(g_before.w),
                              np.asarray(g_after.w))
