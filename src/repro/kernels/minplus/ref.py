"""Pure-jnp oracle for the min-plus query-bound kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF32 = 1 << 29  # plain int: pallas kernels must not capture traced constants


def minplus_bound(s: jax.Array, h: jax.Array, t: jax.Array) -> jax.Array:
    """out[b] = min_{i,j} S[b,i] + H[i,j] + T[b,j] (int32, INF-saturating).

    Accepts rectangular H [P, R] with S [B, P] / T [B, R] — the shard-local
    partial contraction of the model-sharded query bound.
    """
    mid = jnp.min(jnp.minimum(s[:, :, None] + h[None, :, :], INF32), axis=1)
    return jnp.min(jnp.minimum(mid + t, INF32), axis=1)
