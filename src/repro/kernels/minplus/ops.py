"""Jit'd public wrapper for the min-plus kernel with CPU fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.minplus import kernel, ref


def minplus_bound(s: jax.Array, h: jax.Array, t: jax.Array,
                  use_pallas: bool | None = None) -> jax.Array:
    """Eq.-3 upper bound for a query batch: S [B,P], H [P,R], T [B,R]
    int32 → [B].

    P = R is the full bound; P < R contracts a shard-local highway-row
    slice (`core/shard.py` finishes it with a `pmin` over the model axis).
    use_pallas=None auto-selects: the Pallas kernel on TPU, the jnp oracle
    elsewhere; use_pallas=True forces the kernel (interpret-mode off-TPU,
    bit-identical — tests/test_kernels.py pins it).
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        interpret = jax.default_backend() != "tpu"
        return kernel.minplus_pallas(s.astype(jnp.int32),
                                     h.astype(jnp.int32),
                                     t.astype(jnp.int32),
                                     interpret=interpret)
    return ref.minplus_bound(s.astype(jnp.int32), h.astype(jnp.int32),
                             t.astype(jnp.int32))
