"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local+global alternating attention, logit softcaps
[arXiv:2408.00118; hf]."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

ARCH_ID = "gemma2-9b"
FAMILY = "lm"
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def model_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
        d_head=256, d_ff=14336, vocab=256000,
        attn_pattern="local_global", window=4096,
        attn_softcap=50.0, final_softcap=30.0, act="gelu", gated=True,
        rope_theta=10000.0, dtype=jnp.bfloat16)


def reduced_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=512,
        attn_pattern="local_global", window=8, attn_softcap=50.0,
        final_softcap=30.0, act="gelu", gated=True, dtype=jnp.float32,
        q_chunk=16, kv_chunk=16, loss_chunk=16)
