"""Jit'd wrapper: tiled Pallas edge relaxation with jnp fallback.

`BlockedGraph` carries the one-off destination-block tiling; re-tiling is
needed only when topology slots change (insertions), not per wave.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.edge_relax import kernel, ref


@partial(jax.tree_util.register_dataclass,
         data_fields=("src_t", "dstloc_t", "valid_t"),
         meta_fields=("n", "block_v"))
@dataclasses.dataclass(frozen=True)
class BlockedGraph:
    src_t: jax.Array
    dstloc_t: jax.Array
    valid_t: jax.Array
    n: int
    block_v: int


def prepare(src, dst, valid, n: int, block_v: int = 512) -> BlockedGraph:
    src_t, dstloc_t, valid_t, bv = kernel.block_edges(
        np.asarray(src), np.asarray(dst), np.asarray(valid), n, block_v)
    return BlockedGraph(jnp.asarray(src_t), jnp.asarray(dstloc_t),
                        jnp.asarray(valid_t), n, bv)


def edge_relax(keys: jax.Array, bg: BlockedGraph, step,
               use_pallas: bool | None = None) -> jax.Array:
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    interpret = jax.default_backend() != "tpu"
    if use_pallas or interpret is False:
        return kernel.edge_relax_pallas(keys, bg.src_t, bg.dstloc_t,
                                        bg.valid_t, step, bg.n, bg.block_v,
                                        interpret=interpret)
    # jnp fallback on the tiled representation (same math, XLA segment_min).
    flat_dst = (bg.dstloc_t
                + (jnp.arange(bg.src_t.shape[0]) * bg.block_v)[:, None])
    return ref.edge_relax(keys, bg.src_t.reshape(-1), flat_dst.reshape(-1),
                          bg.valid_t.reshape(-1) != 0, step,
                          bg.src_t.shape[0] * bg.block_v)[:bg.n]
