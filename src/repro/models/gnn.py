"""GNN model zoo: SchNet, DimeNet, MACE(-lite), GraphCast.

All four run on the same padded-COO + segment-op substrate as BatchHL's
relaxation sweeps (DESIGN.md §5): message passing is gather → elementwise →
`segment_sum` into destination nodes, with validity masks for padding.

Input convention (`GraphBatch`): node features [N, F], positions [N, 3],
directed edges (src, dst) [E] + edge mask, optional graph ids [N] for
batched small graphs, and (DimeNet only) capped triplet index lists.

Kernel regimes per taxonomy §B.3: SchNet = RBF filter + scatter;
DimeNet = triplet gather (not expressible as SpMM); MACE = equivariant
tensor products (implemented for l ∈ {0,1,2} — see DESIGN.md
§Arch-applicability for the Clebsch–Gordan simplification); GraphCast =
encoder-processor-decoder interaction networks over a grid↔mesh bipartite
topology.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.graphs.segment import masked_segment_sum, masked_segment_mean


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str                  # schnet | dimenet | mace | graphcast
    d_in: int
    d_hidden: int
    d_out: int
    # schnet
    n_interactions: int = 3
    n_rbf: int = 300
    cutoff: float = 10.0
    # dimenet
    n_blocks: int = 6
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    # mace
    n_layers: int = 2
    l_max: int = 2
    correlation: int = 3
    mace_n_rbf: int = 8
    # graphcast
    n_process_layers: int = 16
    mesh_ratio: int = 16       # grid nodes per mesh node (refinement proxy)
    dtype: Any = jnp.float32


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _mlp_params(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{
        "w": (jax.random.normal(k, (a, b), jnp.float32)
              / math.sqrt(a)).astype(dtype),
        "b": jnp.zeros((b,), dtype),
    } for k, (a, b) in zip(ks, zip(dims[:-1], dims[1:]))]


def _mlp_shapes(dims, dtype):
    return [{"w": jax.ShapeDtypeStruct((a, b), dtype),
             "b": jax.ShapeDtypeStruct((b,), dtype)}
            for a, b in zip(dims[:-1], dims[1:])]


def _mlp(layers, x, act=jax.nn.silu, final_act=False):
    for i, l in enumerate(layers):
        x = jnp.einsum("...a,ab->...b", x, l["w"],
                       preferred_element_type=jnp.float32).astype(x.dtype) \
            + l["b"]
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


def _rbf_expand(d, n_rbf, cutoff):
    """Gaussian radial basis with cosine cutoff envelope."""
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = n_rbf / cutoff
    phi = jnp.exp(-gamma * (d[..., None] - centers) ** 2)
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(d / cutoff, 0, 1)) + 1.0)
    return phi * env[..., None]


def _edge_vectors(pos, src, dst):
    vec = pos[dst] - pos[src]
    d = jnp.sqrt(jnp.sum(vec * vec, axis=-1) + 1e-12)
    return vec / d[:, None], d


# ---------------------------------------------------------------------------
# SchNet
# ---------------------------------------------------------------------------

def schnet_init(key, c: GNNConfig):
    ks = jax.random.split(key, 3 + c.n_interactions * 3)
    p = {"embed": _mlp_params(ks[0], [c.d_in, c.d_hidden], c.dtype),
         "out": _mlp_params(ks[1], [c.d_hidden, c.d_hidden, c.d_out],
                            c.dtype)}
    p["blocks"] = [{
        "filter": _mlp_params(ks[2 + 3 * i], [c.n_rbf, c.d_hidden,
                                              c.d_hidden], c.dtype),
        "in_lin": _mlp_params(ks[3 + 3 * i], [c.d_hidden, c.d_hidden],
                              c.dtype),
        "out_mlp": _mlp_params(ks[4 + 3 * i], [c.d_hidden, c.d_hidden,
                                               c.d_hidden], c.dtype),
    } for i in range(c.n_interactions)]
    return p


def schnet_forward(p, batch, c: GNNConfig):
    x = _mlp(p["embed"], batch["node_feat"].astype(c.dtype))
    src, dst, emask = batch["src"], batch["dst"], batch["edge_mask"]
    n = x.shape[0]
    _, d = _edge_vectors(batch["positions"], src, dst)
    rbf = _rbf_expand(d, c.n_rbf, c.cutoff).astype(c.dtype)
    for blk in p["blocks"]:
        w = _mlp(blk["filter"], rbf)                       # [E, H]
        h = _mlp(blk["in_lin"], x)
        msg = h[src] * w
        agg = masked_segment_sum(msg, dst, n, emask)
        x = x + _mlp(blk["out_mlp"], agg)
    return _mlp(p["out"], x)                               # [N, d_out]


# ---------------------------------------------------------------------------
# DimeNet (directional message passing with triplet interactions)
# ---------------------------------------------------------------------------

def dimenet_init(key, c: GNNConfig):
    ks = jax.random.split(key, 5 + c.n_blocks * 4)
    h = c.d_hidden
    p = {
        "edge_embed": _mlp_params(ks[0], [2 * c.d_in + c.n_radial, h],
                                  c.dtype),
        "rbf_lin": _mlp_params(ks[1], [c.n_radial, h], c.dtype),
        "out": _mlp_params(ks[2], [h, h, c.d_out], c.dtype),
        "bilinear": (jax.random.normal(
            ks[3], (c.n_spherical * c.n_radial, c.n_bilinear, h),
            jnp.float32) / math.sqrt(h)).astype(c.dtype),
        "bl_proj": _mlp_params(ks[4], [c.n_bilinear * h, h], c.dtype),
    }
    p["blocks"] = [{
        "msg_mlp": _mlp_params(ks[5 + 4 * i], [h, h, h], c.dtype),
        "tri_kj": _mlp_params(ks[6 + 4 * i], [h, h], c.dtype),
        "upd": _mlp_params(ks[7 + 4 * i], [h, h], c.dtype),
        "out_edge": _mlp_params(ks[8 + 4 * i], [h, h], c.dtype),
    } for i in range(c.n_blocks)]
    return p


def _sbf_expand(d, angle, c: GNNConfig):
    """Simplified spherical basis: sin-radial × cos(m·angle) outer product.

    (The exact DimeNet basis uses spherical Bessel × Legendre; this keeps
    the same [n_spherical × n_radial] feature geometry — noted in DESIGN.)
    """
    dn = jnp.clip(d / c.cutoff, 1e-6, 1.0)
    radial = jnp.sin(jnp.pi * jnp.arange(1, c.n_radial + 1) * dn[..., None])\
        / dn[..., None]                                    # [T, n_radial]
    ms = jnp.arange(c.n_spherical)
    angular = jnp.cos(ms * angle[..., None])               # [T, n_spherical]
    out = angular[..., :, None] * radial[..., None, :]
    return out.reshape(out.shape[:-2] + (c.n_spherical * c.n_radial,))


def dimenet_forward(p, batch, c: GNNConfig):
    src, dst, emask = batch["src"], batch["dst"], batch["edge_mask"]
    n = batch["node_feat"].shape[0]
    e = src.shape[0]
    x = batch["node_feat"].astype(c.dtype)
    _, d = _edge_vectors(batch["positions"], src, dst)
    rbf = _rbf_expand(d, c.n_radial, c.cutoff).astype(c.dtype)

    m = _mlp(p["edge_embed"],
             jnp.concatenate([x[src], x[dst], rbf], axis=-1))  # [E, H]

    # Triplets: edge kj feeds edge ji where dst(kj) == src(ji).
    t_kj, t_ji = batch["tri_kj"], batch["tri_ji"]          # [T] edge ids
    t_mask = batch["tri_mask"]
    angle = batch["tri_angle"]                             # [T]
    d_kj = d[t_kj]
    sbf = _sbf_expand(d_kj, angle, c).astype(c.dtype)      # [T, S*R]

    for blk in p["blocks"]:
        mk = _mlp(blk["tri_kj"], m)[t_kj]                  # [T, H]
        w = jnp.einsum("ts,sbh->tbh", sbf, p["bilinear"],
                       preferred_element_type=jnp.float32).astype(c.dtype)
        tri_msg = (w * mk[:, None, :]).reshape(sbf.shape[0], -1)
        tri_msg = _mlp(p["bl_proj"], tri_msg)              # [T, H]
        agg = masked_segment_sum(tri_msg, t_ji, e, t_mask)
        m = m + _mlp(blk["upd"], jax.nn.silu(
            _mlp(blk["msg_mlp"], m) + agg))
        m = m + _mlp(blk["out_edge"], _mlp(p["rbf_lin"], rbf) * m)

    node_agg = masked_segment_sum(m, dst, n, emask)
    return _mlp(p["out"], node_agg)


# ---------------------------------------------------------------------------
# MACE-lite (E(3)-equivariant, l ∈ {0,1,2}, product correlation stack)
# ---------------------------------------------------------------------------

def mace_init(key, c: GNNConfig):
    h = c.d_hidden
    ks = jax.random.split(key, 3 + c.n_layers * 6)
    p = {"embed": _mlp_params(ks[0], [c.d_in, h], c.dtype),
         "out": _mlp_params(ks[1], [h, h, c.d_out], c.dtype)}
    p["layers"] = [{
        "radial": _mlp_params(ks[2 + 6 * i], [c.mace_n_rbf, h, 3 * h],
                              c.dtype),
        "mix0": _mlp_params(ks[3 + 6 * i], [h, h], c.dtype),
        "mix1": (jax.random.normal(ks[4 + 6 * i], (h, h), jnp.float32)
                 / math.sqrt(h)).astype(c.dtype),
        "mix2": (jax.random.normal(ks[5 + 6 * i], (h, h), jnp.float32)
                 / math.sqrt(h)).astype(c.dtype),
        "prod": _mlp_params(ks[6 + 6 * i], [3 * h, h], c.dtype),
        "upd": _mlp_params(ks[7 + 6 * i], [2 * h, h], c.dtype),
    } for i in range(c.n_layers)]
    return p


def mace_forward(p, batch, c: GNNConfig):
    """Equivariant message passing. Features: s [N,H] scalars,
    v [N,H,3] vectors (l=1), t [N,H,3,3] traceless-symmetric (l=2)."""
    src, dst, emask = batch["src"], batch["dst"], batch["edge_mask"]
    n = batch["node_feat"].shape[0]
    s = _mlp(p["embed"], batch["node_feat"].astype(c.dtype))
    h = s.shape[-1]
    v = jnp.zeros((n, h, 3), c.dtype)
    t = jnp.zeros((n, h, 3, 3), c.dtype)

    u, d = _edge_vectors(batch["positions"], src, dst)     # [E,3], [E]
    rbf = _rbf_expand(d, c.mace_n_rbf, c.cutoff).astype(c.dtype)
    # Spherical harmonics of edge direction (unnormalised):
    y1 = u                                                 # l=1: [E, 3]
    eye = jnp.eye(3, dtype=c.dtype)
    y2 = (u[:, :, None] * u[:, None, :]
          - eye[None] / 3.0)                               # l=2: [E, 3, 3]

    for lay in p["layers"]:
        w = _mlp(lay["radial"], rbf)                       # [E, 3H]
        w0, w1, w2 = jnp.split(w, 3, axis=-1)
        # messages (each term is manifestly equivariant)
        m0 = w0 * s[src]                                   # scalar msg
        m1 = (w1 * s[src])[..., None] * y1[:, None, :] \
            + w1[..., None] * v[src]                       # vector msg
        m2 = (w2 * s[src])[..., None, None] * y2[:, None, :, :] \
            + w2[..., None, None] * t[src]                 # l=2 msg
        a0 = masked_segment_sum(m0, dst, n, emask)
        a1 = masked_segment_sum(m1, dst, n, emask)
        a2 = masked_segment_sum(m2, dst, n, emask)

        # Correlation (order ≤ 3) via invariant contractions:
        inv1 = jnp.sum(a1 * a1, axis=-1)                   # |v|² per channel
        inv2 = jnp.sum(a2 * a2, axis=(-1, -2))             # |t|²
        inv3 = jnp.einsum("nhi,nhij,nhj->nh", a1, a2, a1,
                          preferred_element_type=jnp.float32
                          ).astype(c.dtype)                # v·t·v (order 3)
        prod = _mlp(lay["prod"],
                    jnp.concatenate([a0, inv1 + inv2, inv3], -1))
        s = s + _mlp(lay["upd"], jnp.concatenate([s, prod], -1))
        v = v + jnp.einsum("nhi,hg->ngi", a1, lay["mix1"],
                           preferred_element_type=jnp.float32
                           ).astype(c.dtype)
        t = t + jnp.einsum("nhij,hg->ngij", a2, lay["mix2"],
                           preferred_element_type=jnp.float32
                           ).astype(c.dtype)

    return _mlp(p["out"], s)


# ---------------------------------------------------------------------------
# GraphCast (encoder – processor – decoder over grid↔mesh)
# ---------------------------------------------------------------------------

def _interaction_params(key, h, dtype):
    k1, k2 = jax.random.split(key)
    return {"edge_mlp": _mlp_params(k1, [3 * h, h, h], dtype),
            "node_mlp": _mlp_params(k2, [2 * h, h, h], dtype)}


def _interaction(p, x_src, x_dst, e_feat, src, dst, emask, n_dst):
    """GraphNet block: edge update then node update (sum aggregation)."""
    e_in = jnp.concatenate([e_feat, x_src[src], x_dst[dst]], axis=-1)
    e_new = e_feat + _mlp(p["edge_mlp"], e_in)
    agg = masked_segment_sum(e_new, dst, n_dst, emask)
    x_new = x_dst + _mlp(p["node_mlp"],
                         jnp.concatenate([x_dst, agg], axis=-1))
    return x_new, e_new


def graphcast_init(key, c: GNNConfig):
    h = c.d_hidden
    ks = jax.random.split(key, 6 + c.n_process_layers)
    return {
        "grid_embed": _mlp_params(ks[0], [c.d_in, h], c.dtype),
        "mesh_embed": _mlp_params(ks[1], [4, h], c.dtype),
        "e_g2m": _mlp_params(ks[2], [4, h], c.dtype),
        "e_mesh": _mlp_params(ks[3], [4, h], c.dtype),
        "e_m2g": _mlp_params(ks[4], [4, h], c.dtype),
        "enc": _interaction_params(ks[5], h, c.dtype),
        "proc": [_interaction_params(ks[6 + i], h, c.dtype)
                 for i in range(c.n_process_layers)],
        "dec": _interaction_params(ks[5], h, c.dtype),
        "out": _mlp_params(ks[-1], [h, h, c.d_out], c.dtype),
    }


def _edge_geo(pos_src, pos_dst, src, dst):
    rel = pos_dst[dst] - pos_src[src]
    d = jnp.sqrt(jnp.sum(rel * rel, -1, keepdims=True) + 1e-12)
    return jnp.concatenate([rel, d], axis=-1)              # [E, 4]


def graphcast_forward(p, batch, c: GNNConfig):
    """batch: grid node_feat/positions + mesh topology (precomputed):
    mesh_pos [M,3], g2m (src=grid, dst=mesh), mesh edges, m2g edges."""
    xg = _mlp(p["grid_embed"], batch["node_feat"].astype(c.dtype))
    n_grid = xg.shape[0]
    mesh_pos = batch["mesh_pos"]
    n_mesh = mesh_pos.shape[0]
    xm = _mlp(p["mesh_embed"],
              _edge_geo(mesh_pos, mesh_pos,
                        jnp.zeros((n_mesh,), jnp.int32),
                        jnp.arange(n_mesh)))

    # encoder: grid → mesh
    eg = _mlp(p["e_g2m"], _edge_geo(batch["positions"], mesh_pos,
                                    batch["g2m_src"], batch["g2m_dst"])
              .astype(c.dtype))
    xm, _ = _interaction(p["enc"], xg, xm, eg, batch["g2m_src"],
                         batch["g2m_dst"], batch["g2m_mask"], n_mesh)

    # processor: message passing on the mesh
    em = _mlp(p["e_mesh"], _edge_geo(mesh_pos, mesh_pos,
                                     batch["mesh_src"], batch["mesh_dst"])
              .astype(c.dtype))
    for blk in p["proc"]:
        xm, em = _interaction(blk, xm, xm, em, batch["mesh_src"],
                              batch["mesh_dst"], batch["mesh_mask"], n_mesh)

    # decoder: mesh → grid
    ed = _mlp(p["e_m2g"], _edge_geo(mesh_pos, batch["positions"],
                                    batch["m2g_src"], batch["m2g_dst"])
              .astype(c.dtype))
    xg, _ = _interaction(p["dec"], xm, xg, ed, batch["m2g_src"],
                         batch["m2g_dst"], batch["m2g_mask"], n_grid)
    return _mlp(p["out"], xg)


# ---------------------------------------------------------------------------
# unified entry points
# ---------------------------------------------------------------------------

_INIT = {"schnet": schnet_init, "dimenet": dimenet_init, "mace": mace_init,
         "graphcast": graphcast_init}
_FWD = {"schnet": schnet_forward, "dimenet": dimenet_forward,
        "mace": mace_forward, "graphcast": graphcast_forward}


def init_params(key, c: GNNConfig):
    return _INIT[c.arch](key, c)


def forward(params, batch, c: GNNConfig):
    return _FWD[c.arch](params, batch, c)


def loss_fn(params, batch, c: GNNConfig) -> jax.Array:
    """Node-level regression (molecular energies use graph-sum readout)."""
    pred = forward(params, batch, c)
    tgt = batch["targets"]
    if "graph_ids" in batch:
        n_graphs = tgt.shape[0]  # static: per-graph targets
        pred = masked_segment_sum(pred, batch["graph_ids"], n_graphs,
                                  batch["node_mask"])
        diff = (pred - tgt).astype(jnp.float32)
        return jnp.mean(diff * diff)
    mask = batch.get("node_mask")
    diff = (pred - tgt).astype(jnp.float32)
    sq = jnp.sum(diff * diff, axis=-1)
    if mask is not None:
        sq = jnp.where(mask, sq, 0.0)
        return jnp.sum(sq) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(sq)
