"""BatchHL distance-query serving driver — the paper's system end-to-end.

    PYTHONPATH=src python -m repro.launch.serve --n 2000 --batches 5

Loop per tick: ingest a batch of edge updates (insert+delete mix), run
BatchHL (batch search + batch repair), answer a query batch, report
latencies and labelling size. Optionally verifies every answer against a
BFS oracle (--verify), and checkpoints the labelling for restart.

Sweep backend: ``--backend {auto,jnp,pallas}`` selects the relaxation
engine backend (DESIGN.md §3). The loop owns one `RelaxEngine`, so the
Pallas destination-block tiling is prepared once per tick — from the
*post-update* snapshot, so it covers the tick's inserted edges — and
reused outright across deletion-only ticks, then amortized over every
wave of batch search, batch repair, and the query-side BiBFS in that
tick.

Mesh sharding: ``--mesh host`` runs construction, updates, and queries
through `core/shard.py` on a `make_host_mesh` over the local devices;
``--shards M`` sets the model-axis size (landmark-plane parallelism), the
remaining devices form the data axis (query parallelism). Force a
multi-device CPU host with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``. See DESIGN.md §4.

Backend × mesh compose: under a mesh the engine's plan rides into the
`shard_map` bodies, so ``--backend pallas --mesh host`` launches the
tiled kernel on every device's local planes (``--tile-shards`` shapes the
tiling's vertex-shard grid axis) — one configuration, no silent
downgrade, bit-identical to the unsharded path.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs import generators as gen
from repro.graphs.coo import apply_batch, from_edges, make_batch, to_numpy_adj
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.batch import batchhl_update
from repro.core.engine import RelaxEngine
from repro.core.query import batched_query
from repro.core.shard import (shard_batched_query, shard_batchhl_update,
                              shard_build_labelling)
from repro.core import ref
from repro.checkpoint import manager as ckpt
from repro.launch.mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--deg", type=int, default=4)
    ap.add_argument("--landmarks", type=int, default=16)
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=100)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "jnp", "pallas"),
                    help="relaxation-engine backend for every sweep "
                         "(auto = pallas on TPU, jnp elsewhere)")
    ap.add_argument("--block-v", type=int, default=512,
                    help="destination-block size for the pallas tiling")
    ap.add_argument("--tile-shards", type=int, default=1,
                    help="vertex-shard count of the pallas tiling (the "
                         "kernel grid's leading axis; bit-identical for "
                         "every value)")
    ap.add_argument("--use-minplus-kernel", action="store_true",
                    help="route the Eq.-3 upper bound through the Pallas "
                         "minplus kernel")
    ap.add_argument("--mesh", default="none", choices=("none", "host"),
                    help="run the BatchHL stack sharded over a device mesh "
                         "(host = make_host_mesh over the local devices)")
    ap.add_argument("--shards", type=int, default=1,
                    help="model-axis size of the host mesh: landmark planes "
                         "shard over it, the other devices form the data "
                         "(query) axis; must divide the device count")
    ap.add_argument("--verify", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    mesh = None
    if args.mesh == "host":
        mesh = make_host_mesh(model=args.shards)
        n_dev = len(jax.devices())
        if args.landmarks % n_dev:
            ap.error(f"--landmarks {args.landmarks} must be divisible by "
                     f"the {n_dev} mesh devices (plane sharding)")

    edges = gen.barabasi_albert(args.n, args.deg, seed=0)
    cap = edges.shape[0] + args.batches * args.batch_size + 64
    g = from_edges(args.n, edges, cap)
    landmarks = select_landmarks_by_degree(g, args.landmarks)

    engine = RelaxEngine(backend=args.backend, block_v=args.block_v,
                         shards=args.tile_shards)
    # One plan serves sharded and unsharded call-sites alike: under a mesh
    # it rides into the shard_map bodies as a replicated argument.
    plan = engine.prepare(g)

    t0 = time.time()
    if mesh is not None:
        lab = shard_build_labelling(mesh, g, landmarks, plan=plan)
    else:
        lab = build_labelling(g, landmarks, plan=plan)
    jax.block_until_ready(lab.dist)
    mesh_desc = ("unsharded" if mesh is None else
                 f"mesh data={mesh.shape['data']} model={mesh.shape['model']}")
    print(f"constructed labelling: {args.n} vertices, "
          f"{edges.shape[0]} edges, R={args.landmarks}, "
          f"size={int(lab.label_size())}, {time.time() - t0:.2f}s "
          f"[backend={engine.backend}, {mesh_desc}]")

    # Host-side current edge set, maintained incrementally: a swap-remove
    # list + position map keeps each tick O(batch) instead of rebuilding
    # (and sorting) the full O(E log E) adjacency set every tick.
    edge_list: list[tuple[int, int]] = [
        (int(min(a, b)), int(max(a, b))) for a, b in edges]
    edge_pos = {e: i for i, e in enumerate(edge_list)}

    rng = np.random.default_rng(7)
    for tick in range(args.batches):
        cur_edges = np.asarray(edge_list, np.int32)
        ups = gen.random_batch_updates(
            cur_edges, args.n, n_ins=args.batch_size // 2,
            n_del=args.batch_size // 2, seed=100 + tick, existing=edge_pos)
        batch = make_batch(ups, pad_to=args.batch_size)
        t0 = time.time()
        # One tiling per tick, prepared from the post-update snapshot so it
        # covers inserted edges (the documented engine contract — both
        # backends); deletion-only ticks reuse the cached tiles. Counted
        # inside the update time: it is real per-tick work on the pallas
        # backend.
        has_ins = any(not is_del for (_, _, is_del) in ups)
        g_next = apply_batch(g, batch)
        plan = engine.prepare(g_next, topology_changed=has_ins)
        if mesh is None:
            g, lab, aff = batchhl_update(g, batch, lab, improved=True,
                                         plan=plan, g_new=g_next)
        else:
            g, lab, aff = shard_batchhl_update(mesh, g, batch, lab,
                                               improved=True, plan=plan,
                                               g_new=g_next)
        jax.block_until_ready(lab.dist)
        t_upd = time.time() - t0

        qs = jnp.asarray(rng.integers(0, args.n, args.queries), jnp.int32)
        qt = jnp.asarray(rng.integers(0, args.n, args.queries), jnp.int32)
        t0 = time.time()
        if mesh is None:
            dist = batched_query(g, lab, qs, qt,
                                 use_kernel=args.use_minplus_kernel,
                                 plan=plan)
        else:
            dist = shard_batched_query(mesh, g, lab, qs, qt,
                                       use_kernel=args.use_minplus_kernel,
                                       plan=plan)
        jax.block_until_ready(dist)
        t_q = time.time() - t0

        print(f"tick {tick}: update {t_upd * 1e3:.1f}ms "
              f"({int(jnp.sum(aff))} affected) | "
              f"{args.queries} queries {t_q * 1e3:.1f}ms "
              f"({t_q / args.queries * 1e6:.0f}us/q) | "
              f"label size {int(lab.label_size())}")

        # Fold the tick's updates into the incremental edge set.
        for u, v, is_del in ups:
            k = (min(u, v), max(u, v))
            if is_del:
                i = edge_pos.pop(k, None)
                if i is not None:
                    last = edge_list.pop()
                    if i < len(edge_list):
                        edge_list[i] = last
                        edge_pos[last] = i
            elif k not in edge_pos:
                edge_pos[k] = len(edge_list)
                edge_list.append(k)

        if args.verify:
            adj = to_numpy_adj(g)
            wrong = 0
            n_check = min(64, args.queries)
            for i in range(n_check):
                o = ref.pair_distance(adj, args.n, int(qs[i]), int(qt[i]))
                got = float(dist[i])
                o = got if (o == ref.INF and got >= 1e8) else o
                if int(qs[i]) == int(qt[i]):
                    o = 0
                wrong += int(got != o)
            print(f"  verify: {wrong}/{n_check} mismatches")

        if args.ckpt_dir:
            ckpt.save(args.ckpt_dir, tick + 1,
                      {"dist": lab.dist, "hub": lab.hub,
                       "highway": lab.highway, "landmarks": lab.landmarks})
    engine_desc = ("" if engine.backend == "jnp" else
                   f"retiles={engine.retile_count}/{args.batches + 1} "
                   f"prepares, {engine.stale_cache_retiles} stale-cache "
                   f"catches, tile-shards={engine.shards}, ")
    print(f"serve loop done [backend={engine.backend}, "
          f"{engine_desc}{mesh_desc}]")


if __name__ == "__main__":
    main()
