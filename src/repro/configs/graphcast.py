"""graphcast [gnn]: n_layers=16 d_hidden=512 mesh_refinement=6
aggregator=sum n_vars=227 — encoder-processor-decoder mesh GNN
[arXiv:2212.12794; unverified].

The multi-refinement icosahedral mesh is abstracted as a grid→mesh
assignment with a 16:1 coarsening ratio (refinement-6 proxy); mesh
topology arrives as precomputed input arrays. Output head predicts the
227 surface/atmo variables per grid node.
"""
from repro.models.gnn import GNNConfig

ARCH_ID = "graphcast"
FAMILY = "gnn"
SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")


def model_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID, arch="graphcast", d_in=227, d_hidden=512,
                     d_out=227, n_process_layers=16, mesh_ratio=16)


def reduced_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID + "-smoke", arch="graphcast", d_in=8,
                     d_hidden=32, d_out=8, n_process_layers=2, mesh_ratio=8)
