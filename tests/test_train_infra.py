"""Training infrastructure: optimizer, compression, checkpointing, microbatch
equivalence — the fault-tolerance and distributed-optimization substrate."""
from __future__ import annotations

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train.optimizer import (AdamWConfig, init_opt_state, adamw_update,
                                   _global_norm)
from repro.train import train_step as ts_lib
from repro.checkpoint import manager as ckpt


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(params, cfg)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, state = adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.05


def test_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1e-6, weight_decay=0.0)
    params = {"x": jnp.ones(4)}
    state = init_opt_state(params, cfg)
    huge = {"x": jnp.full(4, 1e9)}
    new_params, _ = adamw_update(params, huge, state, cfg)
    # clipped grad → first-step Adam update magnitude ≈ lr, never 1e9-scaled
    assert float(jnp.max(jnp.abs(new_params["x"] - params["x"]))) < 2.0


def test_int8_ef_compression_still_converges():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, compress="int8_ef")
    params = {"x": jnp.asarray([3.0, -2.0, 1.5])}
    state = init_opt_state(params, cfg)
    assert "ef" in state
    for _ in range(300):
        grads = {"x": 2 * params["x"]}
        params, state = adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.1


def test_error_feedback_accumulates_residual():
    cfg = AdamWConfig(compress="int8_ef")
    params = {"x": jnp.ones(8)}
    state = init_opt_state(params, cfg)
    # tiny + one huge component: int8 quantization of the tiny components
    # underflows, residual must be carried
    grads = {"x": jnp.asarray([1e-6] * 7 + [1.0])}
    _, new_state = adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(new_state["ef"]["x"]))) > 0


def test_microbatch_equals_full_batch():
    """Gradient accumulation must match the monolithic step.

    The accumulated gradient equals the monolithic one only up to f32
    reduction-order noise (~1e-9 absolute here), so the assertions target
    quantities with bounded sensitivity to that noise:

    * loss and the Adam moments m, v are (at step 1) linear/quadratic in
      the gradient — compared tightly in absolute terms;
    * the parameters go through Adam's normalized step m̂/(√v̂+eps), which
      amplifies a sub-noise gradient sign flip into a full ±lr move — so
      they are compared against the 2·lr amplification bound, not against
      a noise-scale atol. (The old atol=2e-5 params-only check was the
      recorded order-dependent flake: any run whose compiled reduction
      order flipped a near-zero gradient's sign moved some parameter by
      ~2e-3.) All state is seeded locally; nothing global is consulted.
    """
    from repro.configs import common as cc
    from repro.models import transformer as tfm
    cfg = cc.get_arch("granite-8b").reduced_config()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    opt = AdamWConfig(lr=1e-3)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)).astype(np.int32))
    batch = {"tokens": toks, "targets": toks}

    s_full = ts_lib.init_train_state(params, opt)
    s_micro = ts_lib.init_train_state(params, opt)
    full = jax.jit(ts_lib.make_lm_train_step(cfg, opt))
    micro = jax.jit(ts_lib.make_lm_train_step(cfg, opt, microbatch=2))
    s_full, aux_f = full(s_full, batch)
    s_micro, aux_m = micro(s_micro, batch)
    np.testing.assert_allclose(float(aux_f["loss"]), float(aux_m["loss"]),
                               rtol=1e-5)
    # Accumulation equivalence proper: first-step moments are clip·(1-b1)·g
    # and (1-b2)·g² — linear/quadratic in the gradient, no amplification.
    for key, atol in (("m", 1e-7), ("v", 1e-9)):
        for a, b in zip(jax.tree_util.tree_leaves(s_full["opt"][key]),
                        jax.tree_util.tree_leaves(s_micro["opt"][key])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=atol)
    # Parameters: bounded by Adam's worst-case step disagreement (≈ 2·lr
    # when a near-zero gradient component flips sign under accumulation).
    flat_f = jax.tree_util.tree_leaves(s_full["params"])
    flat_m = jax.tree_util.tree_leaves(s_micro["params"])
    for a, b in zip(flat_f, flat_m):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=2.5 * opt.lr)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "nested": {"b": jnp.ones(5, jnp.int32),
                       "c": jnp.zeros((), jnp.int32)}}
    d = str(tmp_path / "ck")
    ckpt.save(d, 7, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = ckpt.restore(d, like)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_and_prune(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"x": jnp.ones(3)}
    for s in (1, 5, 3, 9, 7):
        ckpt.save(d, s, tree)
    assert ckpt.latest_step(d) == 9
    ckpt.prune(d, keep=2)
    remaining = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                       if x.startswith("step_"))
    assert remaining == [7, 9]


def test_checkpoint_atomicity_tmp_dirs_ignored(tmp_path):
    """A crashed (partial) write must be invisible to restore."""
    d = str(tmp_path / "ck")
    tree = {"x": jnp.ones(3)}
    ckpt.save(d, 1, tree)
    # simulate a partial write: tmp dir without manifest rename
    os.makedirs(os.path.join(d, ".tmp_step_2"))
    os.makedirs(os.path.join(d, "step_3"))  # no manifest.json → incomplete
    assert ckpt.latest_step(d) == 1


def test_elastic_restore_with_sharding(tmp_path):
    """Restore places leaves with explicitly provided (new) shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.arange(16).reshape(4, 4).astype(jnp.float32)}
    d = str(tmp_path / "ck")
    ckpt.save(d, 2, tree)
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = ckpt.restore(d, tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


def test_train_driver_resume(tmp_path):
    """Kill-and-restart determinism: resuming reproduces the uninterrupted
    run exactly (stateless-seeded data + checkpointed state)."""
    from repro.configs import common as cc
    from repro.models import transformer as tfm
    from repro.launch.train import synth_lm_batch
    cfg = cc.get_arch("minitron-4b").reduced_config()
    opt = AdamWConfig(lr=1e-3)
    step_fn = jax.jit(ts_lib.make_lm_train_step(cfg, opt))
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)

    # run A: 6 uninterrupted steps
    state_a = ts_lib.init_train_state(params, opt)
    for step in range(6):
        state_a, aux_a = step_fn(state_a, synth_lm_batch(step, 2, 16,
                                                         cfg.vocab))
    # run B: 3 steps, checkpoint, "crash", restore, 3 more
    d = str(tmp_path / "ck")
    state_b = ts_lib.init_train_state(params, opt)
    for step in range(3):
        state_b, _ = step_fn(state_b, synth_lm_batch(step, 2, 16, cfg.vocab))
    ckpt.save(d, 3, state_b)
    del state_b
    state_b, start = ckpt.restore(
        d, ts_lib.init_train_state(params, opt))
    assert start == 3
    for step in range(start, 6):
        state_b, aux_b = step_fn(state_b, synth_lm_batch(step, 2, 16,
                                                         cfg.vocab))
    np.testing.assert_allclose(float(aux_a["loss"]), float(aux_b["loss"]),
                               rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(state_a["params"]),
                    jax.tree_util.tree_leaves(state_b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
