"""End-to-end driver: a BatchHL distance-query service under churn.

Simulates the paper's serving scenario through the public façade
(`repro.api.serve`): a power-law network receives batches of edge
updates while answering distance-query traffic; the labelling is
maintained incrementally (never rebuilt), checkpointed, and verified
against a BFS oracle each tick.

Process topology is configuration: pass ``--replicated`` to run the very
same spec as a multi-process tier — one updater publishing versions, two
reader replicas mmap-ing them, a coalescing router in front — instead of
the single-process loop.

    PYTHONPATH=src python examples/dynamic_distance_service.py
    PYTHONPATH=src python examples/dynamic_distance_service.py --replicated
"""
import sys
import tempfile

from repro import api

if __name__ == "__main__":
    replicated = "--replicated" in sys.argv[1:]
    api.serve(
        api.ServeSpec(),
        publish_dir=(tempfile.mkdtemp(prefix="repro_service_")
                     if replicated else None),
        n=3000, batches=4, batch_size=120, queries=256, verify=True,
        **({} if replicated else
           {"ckpt_dir": "/tmp/repro_service_ckpt"}))
