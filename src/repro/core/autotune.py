"""Kernel autotuner: pick the fastest sweep implementation per snapshot shape.

The relaxation engine's Pallas path has three launch-structure knobs —
`block_v` (destination-block tile), `block_e` (tile-row width cap; chunks
power-law hub blocks into bounded rows), `tile_shards` (leading grid axis)
— plus an *implementation* axis the knobs hang off:

    impl="kernel"  the tiled Pallas `edge_relax` kernel (compiled on TPU,
                   interpret-mode elsewhere — correct but slow off-TPU),
    impl="sorted"  the dst-sorted `segment_min(indices_are_sorted=True)`
                   lowering of the identical sweep math (compiled XLA on
                   every platform; sweeps only the occupied edge slots
                   where the jnp reference sweeps all capacity slots).

All candidates are bit-identical (`tests/test_kernel_tuning.py` pins every
config this module may emit against the jnp reference), so tuning is a
pure performance decision: measure each candidate's steady-state sweep
latency on the actual snapshot and keep the winner. Kernel-impl candidates
are only measured where the kernel compiles (TPU) — interpret-mode
timings are not speed-representative and would never win anyway.

Timing discipline (the `roofline --sweep` fix rides on this): the first
call is timed separately as `compile_us`, then `warmup` calls are
discarded, then `steady_us` = min of `iters` timed calls — matching the
`stat=min` convention of `benchmarks/ticks.py`. Picking min-of-k *after*
warmup is what stops the tuner from preferring a config for its compile
speed.

Winners are cached in a `TuneTable` keyed by `(n, capacity, shards)` —
the snapshot *shape*, not its contents: edge churn at fixed shape keeps
the winner, while `coo.grow` / `grow_snapshot` change n/capacity and
therefore force a fresh tune (the same staleness class PR 5's fingerprint
collision guarded against). The table round-trips through a small JSON
file so serve restarts don't re-tune (`launch/serve.py --tune-table`).

CLI (the CI `tune` smoke job):

    PYTHONPATH=src python -m repro.core.autotune \
        --n 2000 --deg 3 --shards 2 --table experiments/tuning.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.segment import masked_segment_min
from repro.kernels.edge_relax import ops as er_ops

INF32 = 1 << 29

#: Kernel-impl candidate grid. Small on purpose: each candidate costs a
#: retile + compile + k timed sweeps, and the table amortizes per shape.
KERNEL_BLOCK_V = (128, 256, 512)
KERNEL_BLOCK_E = (None, 1024)


@dataclasses.dataclass(frozen=True)
class TuneConfig:
    """One point in the tuner's candidate space (hashable, JSON-able).

    `frontier_threshold` is the masked-sweep density knob (DESIGN.md
    §10), tuned separately by `tune_frontier_threshold` — None (the
    default, and what every pre-frontier table deserializes to) leaves
    the engine's configured threshold untouched.
    """
    impl: str                 # "kernel" | "sorted"
    block_v: int              # destination-block tile (kernel impl)
    block_e: int | None       # tile-row width cap; None = widest block
    tile_shards: int          # leading grid axis of the tiling
    frontier_threshold: float | None = None

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.frontier_threshold is None:
            del d["frontier_threshold"]
        return d

    @staticmethod
    def from_dict(d: dict) -> "TuneConfig":
        ft = d.get("frontier_threshold")
        return TuneConfig(impl=d["impl"], block_v=int(d["block_v"]),
                          block_e=(None if d.get("block_e") is None
                                   else int(d["block_e"])),
                          tile_shards=int(d["tile_shards"]),
                          frontier_threshold=(None if ft is None
                                              else float(ft)))


@dataclasses.dataclass(frozen=True)
class TuneResult:
    config: TuneConfig
    steady_us: float          # winner's min-of-k steady latency
    compile_us: float         # winner's first-call (compile) latency
    jnp_us: float             # jnp reference steady latency, same shape
    candidates: tuple         # ((config, compile_us, steady_us), ...)


def table_key(n: int, capacity: int, shards: int) -> str:
    """Tuning-table key: the snapshot *shape*. Deliberately excludes the
    edge-content checksum the plan cache keys on — a tuned winner stays
    valid across edge churn at fixed shape, but never across grow."""
    return f"n={n},cap={capacity},s={shards}"


def candidate_space(shards: int = 1, block_v: int = 512,
                    include_kernel: bool | None = None) -> list[TuneConfig]:
    """Every config the tuner may emit for an engine at (shards, block_v).

    `include_kernel=None` resolves to "is the default backend a TPU" —
    off-TPU the kernel impl runs interpret-mode and is measured by golden
    tests only, never by the tuner.
    """
    if include_kernel is None:
        include_kernel = jax.default_backend() == "tpu"
    cands = [TuneConfig("sorted", block_v, None, shards)]
    if include_kernel:
        for bv in KERNEL_BLOCK_V:
            for be in KERNEL_BLOCK_E:
                for ts in sorted({1, shards}):
                    cands.append(TuneConfig("kernel", bv, be, ts))
    return cands


def measure_compiled(fn, *args, warmup: int = 1,
                     iters: int = 5) -> tuple[float, float]:
    """(compile_us, steady_us) of fn(*args): first call timed apart, then
    `warmup` discarded calls, then min of `iters` timed calls."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    compile_us = (time.perf_counter() - t0) * 1e6
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return compile_us, best * 1e6


def _sweep_inputs(g, r_planes: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    keys = jnp.asarray(rng.integers(0, 2 * g.n, (r_planes, g.n), np.int64)
                       .astype(np.int32))
    hub = jnp.asarray(rng.random((r_planes, g.n)) < 0.02)
    return keys, hub


def tune(g, *, shards: int = 1, block_v: int = 512, r_planes: int = 8,
         include_kernel: bool | None = None, warmup: int = 1,
         iters: int = 3, inf: int = INF32) -> TuneResult:
    """Measure every candidate on snapshot `g`; return the steady-state
    winner plus the jnp-reference latency at the same shape (the number
    the `tune/` bench rows derive the crossover from).

    The measured wave is the production shape: one key2-style sweep
    (step 2, hub clear) vmapped over `r_planes` landmark planes, mask =
    the snapshot's live validity.
    """
    keys, hub = _sweep_inputs(g, r_planes)
    mask = g.valid

    @jax.jit
    def jnp_wave(ks, hb, m):
        def one(k, h):
            s = k[g.src] + 2 * g.w
            cand = jnp.minimum(jnp.where(s < 0, inf, s), inf)
            cand = jnp.where(h[g.dst], cand & ~jnp.int32(1), cand)
            return masked_segment_min(cand, g.dst, g.n, m, inf)
        return jax.vmap(one)(ks, hb)

    _, jnp_us = measure_compiled(jnp_wave, keys, hub, mask,
                                 warmup=warmup, iters=iters)

    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    keep = np.asarray(g.valid)
    measured = []
    for cfg in candidate_space(shards, block_v, include_kernel):
        if cfg.impl == "sorted":
            sg = er_ops.prepare_sorted(src, dst, keep, g.n)

            @jax.jit
            def wave(ks, hb, m, sg=sg):
                return jax.vmap(lambda k, h: er_ops.relax_sweep_sorted(
                    k, sg, m, 2, inf, clear_bit=1, hub=h, w=g.w))(ks, hb)
        else:
            bg = er_ops.prepare_topology(src, dst, keep, g.n,
                                         block_v=cfg.block_v,
                                         shards=cfg.tile_shards,
                                         block_e=cfg.block_e)

            @jax.jit
            def wave(ks, hb, m, bg=bg):
                return jax.vmap(lambda k, h: er_ops.relax_sweep(
                    k, bg, m, 2, inf, clear_bit=1, hub=h, w=g.w))(ks, hb)

        compile_us, steady_us = measure_compiled(wave, keys, hub, mask,
                                                 warmup=warmup, iters=iters)
        measured.append((cfg, compile_us, steady_us))

    best_cfg, best_compile, best_steady = min(measured, key=lambda t: t[2])
    return TuneResult(config=best_cfg, steady_us=best_steady,
                      compile_us=best_compile, jnp_us=jnp_us,
                      candidates=tuple(measured))


#: Candidate grid for the masked sweep's density-fallback knob.
FRONTIER_THRESHOLDS = (0.0625, 0.125, 0.25, 0.5)


def tune_frontier_threshold(g, *, fblock: int = 64, r_planes: int = 8,
                            warmup: int = 1, iters: int = 3,
                            inf: int = INF32,
                            thresholds=FRONTIER_THRESHOLDS) -> float:
    """Pick the masked sweep's density-fallback threshold for `g`'s shape.

    Measures the full jnp reference wave against the masked gathered-
    scatter wave (DESIGN.md §10) at each candidate active fraction
    (rows_cap = ceil(threshold · NR) rows gathered) and returns the
    largest candidate whose masked wave is still faster — the densest
    frontier worth masking on this snapshot shape; anything denser
    should fall back to the full sweep. Returns the smallest candidate
    when masking never wins. The math mirrors `engine.relax_rows`
    inline (this module must not import the engine — it imports us).
    """
    keys, hub = _sweep_inputs(g, r_planes)
    mask = g.valid

    @jax.jit
    def full_wave(ks, hb, m):
        def one(k, h):
            s = k[g.src] + 2 * g.w
            cand = jnp.minimum(jnp.where(s < 0, inf, s), inf)
            cand = jnp.where(h[g.dst], cand & ~jnp.int32(1), cand)
            return masked_segment_min(cand, g.dst, g.n, m, inf)
        return jax.vmap(one)(ks, hb)

    _, full_us = measure_compiled(full_wave, keys, hub, mask,
                                  warmup=warmup, iters=iters)

    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    keep = np.asarray(g.valid)
    best = min(thresholds)
    for th in sorted(thresholds):
        ft = er_ops.prepare_frontier(src, dst, keep, g.n, fblock,
                                     threshold=th)
        # A representative worst-case-at-threshold index vector: the
        # budget fully spent on real rows.
        ridx = jnp.arange(ft.rows_cap, dtype=jnp.int32) % max(ft.nrows, 1)

        @jax.jit
        def masked_wave(ks, hb, m, ft=ft, ridx=ridx):
            src_g, dstg, perm_g, slot_g = ft.gather(ridx)
            emask = slot_g & m[perm_g]
            w_g = jnp.where(slot_g, g.w[perm_g], 0)

            def one(k, h):
                s = k[src_g] + 2 * w_g
                cand = jnp.minimum(jnp.where(s < 0, inf, s), inf)
                cand = jnp.where(h[dstg], cand & ~jnp.int32(1), cand)
                cand = jnp.where(emask, cand, inf)
                return k.at[dstg.ravel()].min(cand.ravel())
            return jax.vmap(one)(ks, hb)

        _, masked_us = measure_compiled(masked_wave, keys, hub, mask,
                                        warmup=warmup, iters=iters)
        if masked_us < full_us:
            best = max(best, th)
    return best


class TuneTable:
    """On-disk (n, capacity, shards) → winning TuneConfig map.

    `path=None` keeps the table in memory only. Persistence is
    whole-file JSON rewrite on every `put` — tables hold a handful of
    shapes, and atomicity (write + rename) keeps a crashed serve run
    from truncating the file.
    """

    def __init__(self, path: str | None = None):
        self.path = path
        self.entries: dict[str, dict] = {}
        if path and os.path.exists(path):
            self.load(path)

    def load(self, path: str) -> None:
        with open(path) as f:
            doc = json.load(f)
        self.entries = dict(doc.get("entries", {}))

    def save(self, path: str | None = None) -> None:
        path = path or self.path
        if not path:
            return
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": 1, "entries": self.entries}, f, indent=1)
        os.replace(tmp, path)

    def get(self, key: str) -> TuneConfig | None:
        ent = self.entries.get(key)
        return TuneConfig.from_dict(ent["config"]) if ent else None

    def put(self, key: str, result: TuneResult) -> None:
        self.entries[key] = {
            "config": result.config.to_dict(),
            "steady_us": round(result.steady_us, 1),
            "compile_us": round(result.compile_us, 1),
            "jnp_us": round(result.jnp_us, 1),
        }
        self.save()

    def __len__(self) -> int:
        return len(self.entries)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Tune the sweep kernel on a synthetic BA snapshot and "
                    "persist the winner (the CI `tune` smoke job).")
    ap.add_argument("--n", type=int, default=2_000)
    ap.add_argument("--deg", type=int, default=3)
    ap.add_argument("--extra-capacity", type=int, default=448)
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--block-v", type=int, default=256)
    ap.add_argument("--r-planes", type=int, default=8)
    ap.add_argument("--table", default="experiments/tuning.json")
    ap.add_argument("--tune-frontier", action="store_true",
                    help="also tune the masked sweep's density-fallback "
                         "threshold and persist it with the winner")
    args = ap.parse_args()

    from repro.graphs import generators as gen
    from repro.graphs.coo import from_edges

    edges = gen.barabasi_albert(args.n, args.deg, seed=0)
    g = from_edges(args.n, edges, edges.shape[0] + args.extra_capacity)
    res = tune(g, shards=args.shards, block_v=args.block_v,
               r_planes=args.r_planes)
    if args.tune_frontier:
        th = tune_frontier_threshold(g, r_planes=args.r_planes)
        res = dataclasses.replace(
            res, config=dataclasses.replace(res.config,
                                            frontier_threshold=th))
        print(f"frontier_threshold={th}")
    table = TuneTable(args.table)
    key = table_key(g.n, int(g.src.shape[0]), args.shards)
    table.put(key, res)
    speedup = res.jnp_us / res.steady_us if res.steady_us else float("inf")
    print(f"{key}: winner={res.config.to_dict()} "
          f"steady={res.steady_us:.1f}us jnp={res.jnp_us:.1f}us "
          f"({speedup:.2f}x) -> {args.table}")
    for cfg, cus, sus in res.candidates:
        print(f"  cand impl={cfg.impl} bv={cfg.block_v} be={cfg.block_e} "
              f"ts={cfg.tile_shards}: steady={sus:.1f}us compile={cus:.1f}us")


if __name__ == "__main__":
    main()
