"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state; the dry-run entrypoint sets XLA_FLAGS *before* any jax import.

Mesh geometry (TPU v5e pods): one pod = 256 chips as (data=16, model=16);
multi-pod = 2 pods → (pod=2, data=16, model=16) with the `pod` axis mapped
across DCN. Axis roles: `data` = batch/FSDP/vertex shards, `model` = tensor/
expert/landmark parallel, `pod` = extra data parallelism across pods.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate mesh for CPU tests: all axes size 1 except data."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
