"""MIND: Multi-Interest Network with Dynamic routing (recsys).

Pipeline: item-embedding gather over user history → B2I capsule routing
(3 iterations) extracting K=4 interest capsules → label-aware attention for
training / max-over-interests scoring for retrieval.

The embedding table is the huge-sparse-table hot path (taxonomy §B.6): a
10⁷-row table row-sharded over the mesh; history lookup is the framework's
own EmbeddingBag substrate (kernels/embed_bag for bag reductions; capsule
routing needs per-item rows so the history gather stays a plain take).
Retrieval scores 10⁶ candidates as one batched matmul over the
candidate-sharded table — never a loop.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MindConfig:
    name: str
    n_items: int
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    hist_len: int = 50
    dtype: Any = jnp.float32
    temperature: float = 0.05


def param_shapes(c: MindConfig) -> dict:
    d = c.embed_dim
    return {
        "item_embed": jax.ShapeDtypeStruct((c.n_items, d), c.dtype),
        "bilinear": jax.ShapeDtypeStruct((d, d), c.dtype),
        "out_proj": jax.ShapeDtypeStruct((d, d), c.dtype),
    }


def param_specs(c: MindConfig, pod: bool = False) -> dict:
    rows = ("model", "pod", "data") if pod else ("model", "data")
    return {"item_embed": P(rows, None),
            "bilinear": P(None, None),
            "out_proj": P(None, None)}


def init_params(key: jax.Array, c: MindConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d = c.embed_dim
    return {
        "item_embed": (jax.random.normal(k1, (c.n_items, d), jnp.float32)
                       * 0.1).astype(c.dtype),
        "bilinear": (jax.random.normal(k2, (d, d), jnp.float32)
                     / math.sqrt(d)).astype(c.dtype),
        "out_proj": (jax.random.normal(k3, (d, d), jnp.float32)
                     / math.sqrt(d)).astype(c.dtype),
    }


def _squash(x: jax.Array) -> jax.Array:
    sq = jnp.sum(x * x, axis=-1, keepdims=True)
    return (sq / (1.0 + sq)) * x / jnp.sqrt(sq + 1e-9)


def extract_interests(params: dict, hist: jax.Array,
                      hist_mask: jax.Array, c: MindConfig) -> jax.Array:
    """B2I dynamic routing. hist [B, L] item ids → interests [B, K, D]."""
    emb = jnp.take(params["item_embed"], hist, axis=0)     # [B, L, D]
    u_hat = jnp.einsum("bld,de->ble", emb, params["bilinear"],
                       preferred_element_type=jnp.float32
                       ).astype(emb.dtype)                 # [B, L, D]
    b_logit = jnp.zeros(hist.shape[:1] + (c.n_interests, hist.shape[1]),
                        jnp.float32)                       # [B, K, L]
    neg = jnp.asarray(-1e9, jnp.float32)
    u_sg = jax.lax.stop_gradient(u_hat)
    for it in range(c.capsule_iters):
        logit = jnp.where(hist_mask[:, None, :], b_logit, neg)
        w = jax.nn.softmax(logit, axis=1)                  # over interests
        src = u_hat if it == c.capsule_iters - 1 else u_sg
        z = jnp.einsum("bkl,bld->bkd", w.astype(src.dtype), src,
                       preferred_element_type=jnp.float32
                       ).astype(src.dtype)
        caps = _squash(z.astype(jnp.float32)).astype(src.dtype)
        if it < c.capsule_iters - 1:
            b_logit = b_logit + jnp.einsum(
                "bkd,bld->bkl", caps, u_sg,
                preferred_element_type=jnp.float32)
    return jnp.einsum("bkd,de->bke", caps, params["out_proj"],
                      preferred_element_type=jnp.float32).astype(caps.dtype)


def label_aware_user_vec(interests: jax.Array, target_emb: jax.Array,
                         power: float = 2.0) -> jax.Array:
    """Label-aware attention (paper eq. 8): pow-sharpened softmax over K."""
    logits = jnp.einsum("bkd,bd->bk", interests, target_emb,
                        preferred_element_type=jnp.float32)
    w = jax.nn.softmax(logits * power, axis=-1)
    return jnp.einsum("bk,bkd->bd", w.astype(interests.dtype), interests,
                      preferred_element_type=jnp.float32
                      ).astype(interests.dtype)


def train_loss(params: dict, batch: dict, c: MindConfig) -> jax.Array:
    """Sampled-softmax with in-batch negatives."""
    interests = extract_interests(params, batch["hist"],
                                  batch["hist_mask"], c)
    tgt = jnp.take(params["item_embed"], batch["target"], axis=0)  # [B, D]
    user = label_aware_user_vec(interests, tgt)            # [B, D]
    logits = jnp.einsum("bd,cd->bc", user, tgt,
                        preferred_element_type=jnp.float32)
    logits = logits / c.temperature
    labels = jnp.arange(logits.shape[0])
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], -1)[:, 0]
    return jnp.mean(lse - gold)


def serve_scores(params: dict, batch: dict, c: MindConfig) -> jax.Array:
    """Online inference: score candidate items. hist [B,L], cands [B,C]
    → scores [B, C] (max over interests)."""
    interests = extract_interests(params, batch["hist"],
                                  batch["hist_mask"], c)
    cand = jnp.take(params["item_embed"], batch["cands"], axis=0)  # [B,C,D]
    scores = jnp.einsum("bkd,bcd->bkc", interests, cand,
                        preferred_element_type=jnp.float32)
    return jnp.max(scores, axis=1)


def retrieval_scores(params: dict, batch: dict, c: MindConfig) -> jax.Array:
    """Retrieval: one query against the full candidate set [C] (10⁶) —
    a single batched matmul against the candidate-sharded embedding rows."""
    interests = extract_interests(params, batch["hist"],
                                  batch["hist_mask"], c)   # [1, K, D]
    cand = jnp.take(params["item_embed"], batch["cands"], axis=0)  # [C, D]
    scores = jnp.einsum("bkd,cd->bkc", interests, cand,
                        preferred_element_type=jnp.float32)
    return jnp.max(scores, axis=1)                         # [1, C]
