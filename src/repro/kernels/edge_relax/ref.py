"""Pure-jnp oracle for the edge-relaxation kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF32 = 1 << 29  # plain int: pallas kernels must not capture traced constants


def edge_relax(keys: jax.Array, src: jax.Array, dst: jax.Array,
               valid: jax.Array, step, n: int,
               w: jax.Array | None = None) -> jax.Array:
    """cand[v] = min over valid edges (u,v) of keys[u] + step·w; INF if none.

    The add saturates: keys and step·w are both non-negative, so an int32
    overflow shows up as a negative sum — clamp those to INF32 instead of
    letting a near-INF key pass a heavy edge as a small key.
    """
    sw = step if w is None else step * w
    s = keys[src] + sw
    cand = jnp.minimum(jnp.where(s < 0, INF32, s), INF32)
    cand = jnp.where(valid, cand, INF32)
    out = jax.ops.segment_min(cand, dst, num_segments=n)
    return jnp.minimum(out, INF32)
