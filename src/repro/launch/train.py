"""Fault-tolerant training driver (end-to-end runnable on CPU).

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b \
        --steps 100 --ckpt-dir /tmp/ckpt --ckpt-every 20

Runs the reduced config by default (CPU container); pass --full on real
hardware. Demonstrates the production loop: stateless-seeded data,
checkpoint/restart (kill it mid-run and rerun — it resumes exactly),
checkpoint pruning, loss logging.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import common as cc
from repro.checkpoint import manager as ckpt
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib


def synth_lm_batch(step: int, batch: int, seq: int, vocab: int) -> dict:
    rng = np.random.default_rng(step)  # stateless: batch = f(step)
    toks = rng.integers(0, vocab, size=(batch, seq + 1)).astype(np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "targets": jnp.asarray(toks[:, 1:])}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--full", action="store_true",
                    help="use the full (pod-scale) config")
    ap.add_argument("--compress-grads", action="store_true",
                    help="int8 error-feedback gradient compression")
    args = ap.parse_args()

    mod = cc.get_arch(args.arch)
    if mod.FAMILY != "lm":
        raise SystemExit("train.py drives LM archs; see examples/ for others")
    cfg = mod.model_config() if args.full else mod.reduced_config()

    from repro.models import transformer as tfm
    opt_cfg = opt_lib.AdamWConfig(
        lr=args.lr, compress="int8_ef" if args.compress_grads else None)
    step_fn = jax.jit(ts_lib.make_lm_train_step(cfg, opt_cfg))

    start = ckpt.latest_step(args.ckpt_dir)
    if start is not None:
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        state_like = ts_lib.init_train_state(params, opt_cfg)
        state, start = ckpt.restore(args.ckpt_dir, state_like)
        print(f"resumed from step {start}")
    else:
        params = tfm.init_params(jax.random.PRNGKey(0), cfg)
        state = ts_lib.init_train_state(params, opt_cfg)
        start = 0
        print("fresh start")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = synth_lm_batch(step, args.batch, args.seq, cfg.vocab)
        state, aux = step_fn(state, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(aux['loss']):.4f} "
                  f"({time.time() - t0:.1f}s)")
        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            path = ckpt.save(args.ckpt_dir, step + 1, state)
            ckpt.prune(args.ckpt_dir, keep=3)
            print(f"checkpoint -> {path}")
    print("done")


if __name__ == "__main__":
    main()
