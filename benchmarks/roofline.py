"""Roofline analysis from compiled dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.roofline \
        --dryrun-dir experiments/dryrun --out experiments/roofline.json

Three terms per (arch × shape), single-pod mesh (256 × TPU v5e):

    compute    = HLO_FLOPs_per_device / 197e12        [s]
    memory     = HLO_bytes_per_device / 819e9         [s]
    collective = collective_bytes_per_device / 50e9   [s]

**Scan-once correction.** XLA's cost_analysis counts a while/scan body
once, not × trip-count. The production LM step scans over layers and over
attention chunks, so raw dry-run numbers undercount by ~L×chunks. This tool
therefore performs dedicated *analysis lowerings* per LM cell — layer stack
unrolled (cfg.unroll_layers), attention/loss unchunked — at 1–3 layers, and
reconstructs full-depth totals from per-layer deltas:

    uniform stacks:      total = c(1) + (L-1)·[c(2)-c(1)]
    alternating (gemma): total = c(1) + (n_loc-1)·[c(3)-c(2)]
                                      + n_glob·[c(2)-c(1)]
    dense+moe (deepseek): total = c(2) + (n_moe-1)·[c(3)-c(2)]

GNN / MIND cells use python-loop layers (no scan) → raw numbers are exact.
BatchHL cells report per-wave terms; wave counts are data-dependent
(≈ affected-region eccentricity, 3–8 on complex networks per the paper's
Fig. 5 distance distribution) and are reported as a multiplier note.

**Measured sweep throughput** (``--sweep``): besides the analytical terms,
this tool can directly measure the BatchHL relaxation-sweep hot loop —
one engine-dispatched wave (key2 extension, all landmark planes vmapped)
per backend, jnp segment-min vs the tiled Pallas edge_relax kernel —
reporting edges/s and the achieved fraction of the HBM roofline. Off-TPU
the Pallas numbers are interpret-mode (correctness-representative, not
speed-representative); on TPU they are the real kernel.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / link

LM_ARCHS = ("gemma2-9b", "minitron-4b", "granite-8b",
            "deepseek-v2-lite-16b", "mixtral-8x22b")


def _analysis_costs(arch: str, shape: str, n_layers: int,
                    overrides: dict | None = None) -> dict:
    """Lower one analysis variant on the single-pod mesh; return per-device
    flops / bytes / collective bytes (everything unrolled & unchunked)."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_production_mesh
    from repro.launch.dryrun import parse_collective_bytes
    from repro.configs import common as cc

    mod = cc.get_arch(arch)
    cfg = mod.model_config()
    sh = cc.LM_SHAPES[shape]
    big = 1 << 20
    cfg = dataclasses.replace(
        cfg, n_layers=n_layers, unroll_layers=True,
        q_chunk=big, kv_chunk=big, loss_chunk=big,
        **(overrides or {}))
    cell = cc.lm_cell(cfg, shape, pod=False)
    mesh = make_production_mesh(multi_pod=False)

    def to_sh(t):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, P))
    with mesh:
        j = jax.jit(cell.step_fn,
                    in_shardings=tuple(to_sh(s) for s in cell.in_specs),
                    out_shardings=to_sh(cell.out_specs))
        comp = j.lower(*cell.arg_specs).compile()
        cost = comp.cost_analysis() or {}
        coll = parse_collective_bytes(comp.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total_bytes"])}


def reconstruct_lm(arch: str, shape: str) -> dict:
    """Full-depth per-device costs via per-layer deltas (see module doc)."""
    from repro.configs import common as cc
    cfg = cc.get_arch(arch).model_config()
    L = cfg.n_layers

    def combine(base, deltas):
        return {k: base[k] + sum(m * d[k] for m, d in deltas)
                for k in base}

    if arch == "gemma2-9b":                      # alternating local/global
        c1 = _analysis_costs(arch, shape, 1)
        c2 = _analysis_costs(arch, shape, 2)
        c3 = _analysis_costs(arch, shape, 3)
        n_loc, n_glob = (L + 1) // 2, L // 2
        loc = {k: c3[k] - c2[k] for k in c1}
        glob = {k: c2[k] - c1[k] for k in c1}
        return combine(c1, [(n_loc - 1, loc), (n_glob, glob)])
    if arch == "deepseek-v2-lite-16b":           # 1 dense + (L-1) moe
        c2 = _analysis_costs(arch, shape, 2)
        c3 = _analysis_costs(arch, shape, 3)
        moe = {k: c3[k] - c2[k] for k in c2}
        return combine(c2, [(L - 2, moe)])
    # uniform stacks (minitron, granite, mixtral)
    c1 = _analysis_costs(arch, shape, 1)
    c2 = _analysis_costs(arch, shape, 2)
    lay = {k: c2[k] - c1[k] for k in c1}
    return combine(c1, [(L - 1, lay)])


def model_flops_per_device(arch: str, shape: str, devices: int = 256):
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D + exact-window attention (serve),
    using active params for MoE. None for non-LM families."""
    from repro.configs import common as cc
    mod = cc.get_arch(arch)
    if mod.FAMILY != "lm":
        return None
    cfg = mod.model_config()
    sh = cc.LM_SHAPES[shape]
    n_active = cfg.active_params_count if cfg.moe else cfg.params_count
    if sh["kind"] == "train":
        tokens = sh["batch"] * sh["seq"]
        return 6.0 * n_active * tokens / devices
    tokens = sh["batch"] * (sh["seq"] if sh["kind"] == "prefill" else 1)
    base = 2.0 * n_active * tokens / devices
    # ideal attention reads: local layers see ≤window, global see kv_len
    kv = sh["seq"]
    b = sh["batch"]
    per_layer_kv = []
    for li in range(cfg.n_layers):
        local = (cfg.attn_pattern == "swa"
                 or (cfg.attn_pattern == "local_global" and li % 2 == 0))
        per_layer_kv.append(min(cfg.window, kv) if local else kv)
    if sh["kind"] == "prefill":
        # causal: avg half the context, capped by window
        attn = sum(4.0 * b * cfg.n_heads * cfg.d_head
                   * min(w, kv) * kv / 2 for w in per_layer_kv)
    else:
        attn = sum(4.0 * b * cfg.n_heads * cfg.d_head * w
                   for w in per_layer_kv)
    return base + attn / devices


def build_table(dryrun_dir: str, do_lm_reconstruct: bool = True) -> list:
    rows = []
    for fname in sorted(os.listdir(dryrun_dir)):
        if not fname.endswith("__single.json"):
            continue
        rec = json.load(open(os.path.join(dryrun_dir, fname)))
        arch, shape = rec["arch"], rec["shape"]
        raw = {"flops": rec["cost"]["flops"] or 0.0,
               "bytes": rec["cost"]["bytes accessed"] or 0.0,
               "coll": rec["collectives"]["total_bytes"]}
        method = "raw (loop-free)"
        costs = raw
        if do_lm_reconstruct and arch in LM_ARCHS:
            costs = reconstruct_lm(arch, shape)
            method = "reconstructed (unrolled analysis lowerings)"
        elif arch == "batchhl":
            method = "per-wave (multiply by measured wave count 3-8)"
        terms = {
            "compute_s": costs["flops"] / PEAK_FLOPS,
            "memory_s": costs["bytes"] / HBM_BW,
            "collective_s": costs["coll"] / LINK_BW,
        }
        dominant = max(terms, key=lambda k: terms[k])
        mf = model_flops_per_device(arch, shape)
        rows.append({
            "arch": arch, "shape": shape, "method": method,
            "per_device": costs, "terms_s": terms,
            "dominant": dominant.replace("_s", ""),
            "model_flops_per_device": mf,
            "useful_ratio": (mf / costs["flops"])
            if (mf and costs["flops"]) else None,
            "memory_peak_bytes": rec["memory"].get("temp_bytes"),
            "argument_bytes": rec["memory"].get("argument_bytes"),
            "collective_mix": rec["collectives"]["per_type_bytes"],
        })
    return rows


def sweep_throughput(sizes=((2_000, 3), (10_000, 4)), r_planes: int = 16,
                     backends=("jnp", "pallas", "pallas-tuned"),
                     block_v: int = 512) -> list:
    """Measure one engine relaxation wave per backend: edges/s + roofline %.

    Bytes per wave (per landmark plane): the edge slice (src, dst/dstloc,
    mask: 3×4 B/edge) + the key plane read and the candidate plane written
    (2×4 B/vertex) — the memory floor the kernel docstring derives.

    Timing routes through `autotune.measure_compiled`: the first (compile)
    call is timed apart and reported in `derived`, and `us_per_call` is
    the min-of-k *steady-state* latency after a discarded warmup —
    matching the stat=min convention of `benchmarks/ticks.py`. (The old
    `cm.timeit` median folded the compile call into the statistic, which
    made every sweep row compile-dominated at these sizes.)

    The "pallas-tuned" pseudo-backend runs the same engine with
    `autotune=True` — the row the jnp/pallas crossover is read from.
    """
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.graphs import generators as gen
    from repro.graphs.coo import from_edges
    from repro.core.autotune import measure_compiled
    from repro.core.engine import RelaxEngine, relax_sweep
    from repro.core.labelling import INF_KEY2
    from benchmarks import common as cm

    rows = []
    for n, deg in sizes:
        edges = gen.barabasi_albert(n, deg, seed=0)
        g = from_edges(n, edges, edges.shape[0] + 64)
        e_valid = int(2 * edges.shape[0])
        rng = np.random.default_rng(0)
        keys = jnp.asarray(
            rng.integers(0, 2 * n, (r_planes, n)).astype(np.int32))
        hub = jnp.asarray(rng.random((r_planes, n)) < 0.01)

        for backend in backends:
            engine = RelaxEngine(backend=backend.split("-")[0],
                                 block_v=block_v,
                                 autotune=backend == "pallas-tuned")
            plan = engine.prepare(g)

            @jax.jit
            def wave(ks, hb):
                return jax.vmap(
                    lambda k, h: relax_sweep(plan, g, k, 2, INF_KEY2,
                                             hub=h, clear_bit=1))(ks, hb)

            compile_us, steady_us = measure_compiled(wave, keys, hub,
                                                     warmup=1, iters=5)
            t = steady_us / 1e6
            edges_per_s = e_valid * r_planes / t
            bytes_per_wave = r_planes * (e_valid * 3 * 4 + 2 * n * 4)
            frac = (bytes_per_wave / t) / HBM_BW
            rows.append(cm.emit(
                f"roofline/sweep/n{n}/{backend}", t,
                f"edges_per_s={edges_per_s:.3e};hbm_frac={frac:.4f};"
                f"R={r_planes};compile_us={compile_us:.1f};"
                f"impl={plan.impl if plan.backend == 'pallas' else 'jnp'}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.json")
    ap.add_argument("--no-reconstruct", action="store_true")
    ap.add_argument("--sweep", action="store_true",
                    help="measure jnp-vs-pallas relaxation-sweep throughput "
                         "(no dry-run artifacts needed)")
    args = ap.parse_args()
    if args.sweep:
        rows = sweep_throughput()
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump([dict(zip(("name", "us_per_call", "derived"),
                                r.split(",", 2))) for r in rows], f, indent=1)
        return
    rows = build_table(args.dryrun_dir,
                       do_lm_reconstruct=not args.no_reconstruct)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    for r in rows:
        t = r["terms_s"]
        ratio = (f" useful={r['useful_ratio']:.2f}"
                 if r["useful_ratio"] else "")
        print(f"{r['arch']:22s} {r['shape']:14s} "
              f"comp={t['compute_s'] * 1e3:9.3f}ms "
              f"mem={t['memory_s'] * 1e3:9.3f}ms "
              f"coll={t['collective_s'] * 1e3:9.3f}ms "
              f"dom={r['dominant']:10s}{ratio}")


if __name__ == "__main__":
    main()
