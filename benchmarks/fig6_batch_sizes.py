"""Paper Figure 6: total time (one batch update + 1000 queries, amortized
per query) vs batch size, BHL⁺ against the BiBFS online baseline."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.graphs.coo import make_batch, INF_D
from repro.core.batch import batchhl_update
from repro.core.query import batched_query, bounded_bibfs
from benchmarks import common as cm

SIZES = (32, 64, 128, 256, 512)
N_QUERIES = 256


def run() -> list[str]:
    rows = []
    rng = np.random.default_rng(5)
    inst = cm.build_instance("ba_10k")
    qs = jnp.asarray(rng.integers(0, inst.n, N_QUERIES), jnp.int32)
    qt = jnp.asarray(rng.integers(0, inst.n, N_QUERIES), jnp.int32)
    for size in SIZES:
        ups = cm.update_stream(inst.edges, inst.n, size, "mixed", seed=17)
        b = make_batch(ups, pad_to=size)

        def upd_and_query():
            g2, lab2, _ = batchhl_update(inst.g, b, inst.lab)
            return batched_query(g2, lab2, qs, qt)

        t = cm.timeit(upd_and_query, iters=2)
        rows.append(cm.emit(f"fig6/ba_10k/BHL+/batch{size}",
                            t / N_QUERIES, f"queries={N_QUERIES}"))
    # BiBFS baseline: queries only (no labelling to maintain)
    empty = jnp.zeros((0,), jnp.int32)
    t = cm.timeit(lambda: bounded_bibfs(
        inst.g, empty, qs, qt, jnp.full((N_QUERIES,), INF_D), 64), iters=2)
    rows.append(cm.emit("fig6/ba_10k/BiBFS", t / N_QUERIES,
                        f"queries={N_QUERIES}"))
    return rows


if __name__ == "__main__":
    run()
