"""The relaxation engine: one backend-dispatch seam for every sweep.

Every edge-relaxation wave in the system — offline construction
(`core/construct.py`), batch search Algos 2–3 and batch repair Algo 4
(`core/batch.py`), and the bounded-BiBFS frontier expansion
(`core/query.py`) — is an instance of one primitive:

    cand[v] = min over valid edges (u, v) of extend(keys[u], v)
    extend(k, v) = min(k + step, inf), with `clear_bit` cleared when v is
                   a hub landmark (the ⊕ operator on key2/key4 encodings,
                   see DESIGN.md §1–§2)

`relax_sweep` below routes that primitive through either the pure-jnp
segment-min reference (XLA scatter-min) or the tiled Pallas `edge_relax`
kernel, selected by the `RelaxPlan`'s static backend tag — the same
dispatch shape as `query_upper_bound(use_kernel=...)` → the minplus kernel.

The Pallas path needs a destination-block tiling of the edge list
(`BlockedGraph`).  Tiling is a host-side O(E log E) sort, so `RelaxEngine`
caches it per graph snapshot and rebuilds only when topology slots change:
deletions merely flip validity bits (re-tiled on device each sweep through
the stored slot permutation), while insertions rewrite src/dst slots and
invalidate the tiling (see DESIGN.md §3 for the full contract).
`launch/serve.py` holds one engine for the serving loop so the tiling is
amortized across all waves of a tick and across deletion-only ticks.

Plans are mesh-transparent: the tiling is organized as `shards` contiguous
block_v-aligned vertex shards (the leading tile axis, bit-identical for
every shard count), and `core/shard.py` passes the whole plan into its
`shard_map` bodies as replicated leaves — every device launches the same
kernel over its local landmark planes. One prepared plan therefore serves
sharded and unsharded call-sites alike; a mesh→no-mesh round trip keeps
the cache (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.coo import Graph
from repro.graphs.segment import masked_segment_min
from repro.core import autotune as tune_mod
from repro.kernels.edge_relax import ops as er_ops
from repro.kernels.edge_relax.ops import BlockedGraph, FrontierTiles, SortedGraph

BACKENDS = ("jnp", "pallas")


@partial(jax.tree_util.register_dataclass,
         data_fields=("tiles", "sorted_tiles", "frontier"),
         meta_fields=("backend", "impl"))
@dataclasses.dataclass(frozen=True)
class RelaxPlan:
    """How to run sweeps on one graph snapshot.

    A pytree: `tiles` / `sorted_tiles` / `frontier` (the prepared edge
    representations, None when unused) flow through jit as data;
    `backend` and `impl` are metadata, so dispatch below is resolved at
    trace time — each (backend, impl) gets its own executable, with no
    runtime branching inside the compiled sweep loops.

    `impl` selects the Pallas-backend implementation the autotuner picked
    (see `core/autotune.py`): "kernel" = the tiled Pallas kernel on
    `tiles`, "sorted" = the dst-sorted compiled segment-min twin on
    `sorted_tiles`. Both are bit-identical to the jnp reference.

    `frontier` (any backend) carries the change-propagation row tiling
    that lets `core/batch.py` relax only the destination blocks the
    batch's frontier touches (DESIGN.md §10). Whether it is present is
    pytree *structure*, so the fixpoint loops specialize at trace time:
    plans without it compile exactly the pre-frontier full-sweep program.
    """
    tiles: BlockedGraph | None
    backend: str
    sorted_tiles: SortedGraph | None = None
    impl: str = "kernel"
    frontier: FrontierTiles | None = None


#: Default plan: the pure-jnp reference path, no tiling required.
JNP_PLAN = RelaxPlan(tiles=None, backend="jnp")


def relax_sweep(plan: RelaxPlan | None, g: Graph, keys: jax.Array,
                step, inf, *, hub: jax.Array | None = None,
                clear_bit: int = 0,
                edge_mask: jax.Array | None = None) -> jax.Array:
    """One relaxation wave of `keys` [V] over the edges of `g`.

    plan=None (or backend "jnp") runs the segment-min reference on the COO
    arrays; backend "pallas" runs the tiled kernel (interpret-mode off-TPU,
    so results are bit-identical across backends — the parity tests assert
    this). `edge_mask` defaults to g.valid and is always in original
    edge-slot order; `hub`/`clear_bit` realize key2/key4 path extension.

    The metric is weighted: the extend adds step·w(u,v) from the graph's
    per-slot weight column and saturates at `inf` (int32 wrap → inf).
    Unweighted graphs carry w ≡ 1 on occupied slots, which makes the
    weighted extend bit-identical to the historical `keys + step`.
    """
    mask = g.valid if edge_mask is None else edge_mask
    if plan is None or plan.backend == "jnp":
        s = keys[g.src] + step * g.w
        cand = jnp.minimum(jnp.where(s < 0, inf, s), inf)
        if hub is not None and clear_bit:
            cand = jnp.where(hub[g.dst], cand & ~jnp.int32(clear_bit), cand)
        return masked_segment_min(cand, g.dst, g.n, mask, inf)
    if plan.backend == "pallas":
        if plan.impl == "sorted":
            return er_ops.relax_sweep_sorted(keys, plan.sorted_tiles, mask,
                                             step, inf, clear_bit=clear_bit,
                                             hub=hub, w=g.w)
        return er_ops.relax_sweep(keys, plan.tiles, mask, step, inf,
                                  clear_bit=clear_bit, hub=hub, w=g.w)
    raise ValueError(f"unknown backend {plan.backend!r}; pick from {BACKENDS}")


def gather_rows(plan: RelaxPlan, g: Graph, ridx: jax.Array):
    """Materialize the masked sweep's active tile rows (plane-independent).

    `ridx` int32[rows_cap] names tile rows of `plan.frontier`, sentinel-
    filled to its static size. Returns (src_g, dstg, valid_g, w_g), each
    [rows_cap, BE]: source vertex, global destination vertex, per-slot
    validity (tile occupancy ∧ current edge validity through the stored
    slot permutation — the same device re-tiling trick BlockedGraph
    uses), and edge weight. Gathered once per wave, shared by every
    landmark plane's `relax_rows`.
    """
    src_g, dstg, perm_g, slot_g = plan.frontier.gather(ridx)
    valid_g = slot_g & g.valid[perm_g]
    w_g = jnp.where(slot_g, g.w[perm_g], 0)
    return src_g, dstg, valid_g, w_g


def relax_rows(keys: jax.Array, out: jax.Array, src_g, dstg, emask_g, w_g,
               step, inf, *, hub: jax.Array | None = None,
               clear_bit: int = 0, bound: jax.Array | None = None
               ) -> jax.Array:
    """One masked relaxation wave: scatter-min row candidates into `out`.

    The same extend/hub-clear math as `relax_sweep`, restricted to the
    gathered rows: candidates from masked-off slots (and the sentinel
    fill rows, whose dstg is 0 and emask false) become `inf`, so the
    scatter-min is a no-op for them. `bound`, when given, applies the
    per-destination acceptance filter (`cand <= bound[dst]`) per edge —
    equivalent because the bound is constant per destination, and
    required here because the masked path never materializes the
    per-destination segment min before combining into `out`.
    """
    s = keys[src_g] + step * w_g
    cand = jnp.minimum(jnp.where(s < 0, inf, s), inf)
    if hub is not None and clear_bit:
        cand = jnp.where(hub[dstg], cand & ~jnp.int32(clear_bit), cand)
    if bound is not None:
        cand = jnp.where(cand <= bound[dstg], cand, inf)
    cand = jnp.where(emask_g, cand, inf)
    return out.at[dstg.ravel()].min(cand.ravel())


class RelaxEngine:
    """Host-side owner of the backend choice and the tiling cache.

    backend:  "jnp"    — segment-min reference everywhere (the default off
                         TPU; zero host syncs, zero tiling cost),
              "pallas" — tiled kernel (compiled on TPU, interpret-mode
                         elsewhere; parity-tested against jnp),
              "auto"   — "pallas" on TPU, "jnp" otherwise.
    block_v:  destination-block size for the tiling (kernel output tile).
    shards:   vertex-shard count of the tiling (leading tile axis; the
              kernel grid walks (shard, block)). Bit-identical for every
              value — a launch-structure knob that lets the plan compose
              with `shard_map` meshes (`core/shard.py`) and, at scale,
              lets each device own one slice.
    """

    def __init__(self, backend: str = "auto", block_v: int = 512,
                 shards: int = 1, cache_plans: int = 2,
                 block_e: int | None = None, autotune: bool = False,
                 tune_table: "tune_mod.TuneTable | str | None" = None,
                 frontier: bool = False, frontier_threshold: float = 0.25,
                 frontier_block: int = 64):
        if backend == "auto":
            backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
        if backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {backend!r}; pick from {BACKENDS + ('auto',)}")
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if cache_plans < 1:
            raise ValueError(f"cache_plans must be >= 1, got {cache_plans}")
        self.backend = backend
        self.block_v = block_v
        self.shards = shards
        self.block_e = block_e
        self.cache_plans = cache_plans
        # Frontier-proportional sweeps (DESIGN.md §10): when enabled,
        # prepared plans additionally carry the change-propagation row
        # tiling so batch search/repair can relax only the destination
        # blocks the batch footprint touches. Orthogonal to the backend —
        # even jnp plans get tiled (and therefore pay the tiling sync).
        self.frontier = frontier
        self.frontier_threshold = frontier_threshold
        self.frontier_block = frontier_block
        # Autotuning (core/autotune.py): pick impl + tile shape per
        # snapshot shape, memoized in a TuneTable (optionally on disk so
        # serve restarts skip the measurement entirely).
        self.autotune = autotune
        if isinstance(tune_table, str):
            tune_table = tune_mod.TuneTable(tune_table)
        self.tune_table = (tune_table if tune_table is not None
                           else (tune_mod.TuneTable() if autotune else None))
        self._tuned_cfg: tune_mod.TuneConfig | None = None
        self._plan: RelaxPlan | None = None
        self._fingerprint: tuple | None = None
        # Fingerprint-keyed LRU of prepared plans. The serving pipeline
        # keeps two snapshots live at once (committed N answering queries,
        # N+1 under construction), so re-preparing for either must not
        # thrash an O(E log E) retile — the default capacity of 2 covers
        # exactly that pattern. The key also carries the tuned config, so
        # adopting a new winner can never serve tiles shaped for the old
        # one. Prepared plans are immutable, so evicted entries embedded
        # in older snapshots stay valid.
        self._plans: dict[tuple, RelaxPlan] = {}
        self.retile_count = 0  # observability: serve/benchmarks report this
        self.stale_cache_retiles = 0  # fingerprint mismatches caught below
        self.plan_cache_hits = 0  # keyed-cache hits (no retile needed)
        self.tune_count = 0  # tuner measurement runs (table misses)

    @property
    def plan_alignment(self) -> int:
        """Vertex-count alignment unit for grow-in-place (DESIGN.md §6).

        Grown vertex counts are rounded up to block_v · shards so the
        grown tiling keeps full destination blocks and an even per-shard
        block split — the same shape a fresh prepare at that size would
        produce. Reported for *both* backends (the jnp path needs no
        alignment) so a growth stream reaches the same sizes whichever
        backend serves it, keeping cross-backend state bit-comparable.
        """
        return self.block_v * self.shards

    @staticmethod
    def _snapshot_fingerprint(g: Graph) -> tuple:
        """Cheap identity of a snapshot's topology slots.

        (n, capacity, occupied-slot count, all-slot src/dst checksum). The
        checksum covers *every* slot — free slots included — because
        insertions rewrite free slots (changing it) while deletions only
        flip validity bits (leaving it untouched). It is *slot-position
        sensitive* — each slot's hash is mixed with its index — because
        the tiling a fingerprint keys embeds a slot permutation: two
        snapshots holding the same edge multiset in different slot
        layouts must not collide, or one's per-slot validity mask gets
        applied through the other's permutation and the sweep relaxes
        the wrong edges (a commutative sum had exactly this collision;
        the batch-split property test pins it). n and capacity being
        part of the key is what makes grow-in-place safe here: a grown
        snapshot can never alias a pre-growth fingerprint, so growth is
        always a clean retile, never a stale-tile reuse (DESIGN.md §6).
        Two tiny device reductions + one host sync; negligible next to
        the O(E log E) retile it guards.
        """
        occupied = int(jnp.sum(g.valid))
        idx = jnp.arange(g.src.shape[0], dtype=jnp.uint32)
        slot_h = (g.src.astype(jnp.uint32) * jnp.uint32(2654435761)
                  + g.dst.astype(jnp.uint32) * jnp.uint32(40503)) \
            ^ (idx * jnp.uint32(2246822519))
        chk = int(jnp.sum(slot_h))
        return (g.n, g.src.shape[0], occupied, chk)

    def _cache_is_stale(self, g: Graph) -> bool:
        """True when `g`'s topology slots don't match the cached tiling.

        Legitimate reuse (deletion-only churn since tiling) keeps n,
        capacity, and the all-slot checksum fixed and can only *shrink* the
        occupied count; anything else — an insertion the caller forgot to
        flag, or a different graph entirely — mismatches.
        """
        n, cap, occupied, chk = self._fingerprint
        n2, cap2, occupied2, chk2 = self._snapshot_fingerprint(g)
        return (n2, cap2, chk2) != (n, cap, chk) or occupied2 > occupied

    def prepare(self, g: Graph, topology_changed: bool = True,
                verify_cache: bool = True) -> RelaxPlan:
        """Plan sweeps for snapshot `g`, reusing the cached tiling when the
        caller can vouch that no topology slot changed since the last
        prepare (deletion-only batches flip validity bits only).

        The vouch is verified: a snapshot fingerprint recorded at tiling
        time is re-checked on every cache hit, and a mismatch (slots
        changed, or a different graph entirely) forces a retile instead of
        silently serving stale tiles (counted in `stale_cache_retiles`).
        The check costs two small device reductions + a host sync;
        `verify_cache=False` skips it for tight inner loops whose snapshot
        is *derived* from the tiled one by deletions alone (the engine's
        own variant drivers, `uhl_update`/`batchhl_update_split`, where a
        per-step sync would serialize the loop on transfer latency).

        Topology changes route through a fingerprint-keyed LRU (capacity
        `cache_plans`): preparing a snapshot whose slots match a cached
        tiling — e.g. alternating between the two live snapshots of the
        serving pipeline — returns it without the O(E log E) retile
        (`plan_cache_hits` counts these; the fingerprint sync is the same
        one a retile would pay).

        On the jnp backend this is free — no tiling, no host sync —
        unless `frontier` is enabled, in which case jnp plans carry (and
        cache) the change-propagation tiling like any other and pay the
        same fingerprint sync.
        """
        if self.backend == "jnp" and not self.frontier:
            return JNP_PLAN
        cfg = self._ensure_tuned(g) if self.backend == "pallas" else None
        if self._plan is not None and not topology_changed:
            if not (verify_cache and self._cache_is_stale(g)):
                return self._plan
            self.stale_cache_retiles += 1  # the vouch was wrong — re-key
        fp = self._snapshot_fingerprint(g)
        key = fp + ((cfg.impl, cfg.block_v, cfg.block_e, cfg.tile_shards)
                    if cfg else ())
        if self.frontier:
            key = key + ("frontier", self.frontier_block,
                         self.frontier_threshold)
        plan = self._plans.pop(key, None)
        if plan is None:
            # Host sync: pull the slot arrays once per topology change and
            # prepare only the occupied slots (free slots get src/dst
            # rewritten by the insertion that occupies them, forcing a
            # re-prepare).
            src = np.asarray(g.src)
            dst = np.asarray(g.dst)
            keep = np.asarray(g.valid)
            ft = (er_ops.prepare_frontier(
                      src, dst, keep, g.n, self.frontier_block,
                      threshold=self.frontier_threshold)
                  if self.frontier else None)
            if self.backend == "jnp":
                plan = RelaxPlan(tiles=None, backend="jnp", frontier=ft)
            elif cfg is not None and cfg.impl == "sorted":
                plan = RelaxPlan(tiles=None, backend="pallas",
                                 sorted_tiles=er_ops.prepare_sorted(
                                     src, dst, keep, g.n),
                                 impl="sorted", frontier=ft)
            else:
                tiling_s = cfg.tile_shards if cfg else self.shards
                plan = RelaxPlan(tiles=er_ops.prepare_topology(
                    src, dst, keep, g.n, self.block_v, tiling_s,
                    self.block_e), backend="pallas", frontier=ft)
            self.retile_count += 1
        else:
            self.plan_cache_hits += 1
        self._plans[key] = plan  # (re)insert as most-recently used
        while len(self._plans) > self.cache_plans:
            self._plans.pop(next(iter(self._plans)))
        self._plan, self._fingerprint = plan, fp
        return plan

    def _ensure_tuned(self, g: Graph) -> "tune_mod.TuneConfig | None":
        """Resolve (and adopt) the tuned config for `g`'s shape.

        Table lookups are keyed (n, capacity, shards) — edge churn at
        fixed shape reuses the winner with zero measurement; growth
        changes the key and re-tunes (`tune_count` counts measurement
        runs). Adopting a kernel-impl winner updates `block_v`/`block_e`
        so `plan_alignment` — the contract `core/growth.py` sizes grown
        snapshots against — always reflects the tiles actually served.
        """
        if not self.autotune:
            return None
        key = tune_mod.table_key(g.n, int(g.src.shape[0]), self.shards)
        cfg = self.tune_table.get(key)
        if cfg is None:
            result = tune_mod.tune(g, shards=self.shards,
                                   block_v=self.block_v)
            self.tune_table.put(key, result)
            self.tune_count += 1
            cfg = result.config
        if cfg != self._tuned_cfg:
            self._tuned_cfg = cfg
            if cfg.impl == "kernel":
                self.block_v = cfg.block_v
                self.block_e = cfg.block_e
            if cfg.frontier_threshold is not None:
                self.frontier_threshold = cfg.frontier_threshold
        return cfg
