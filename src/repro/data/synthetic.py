"""Stateless-seeded synthetic data: batch = f(layout, step).

Every input pipeline is a pure function of (layout, seed/step) so a
restarted job regenerates the exact stream — the fault-tolerance contract
(DESIGN.md §4). `materialize(layout, seed)` builds real arrays for smoke
tests/examples; `as_specs(layout)` turns the same layout into
ShapeDtypeStructs for dry-run lowering — one source of truth, no drift.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

# A layout is a dict: name -> (shape tuple, dtype, kind)
# kind ∈ {"tokens:<vocab>", "ids:<max>", "float", "bool", "pos", "angle"}


def as_specs(layout: dict) -> dict:
    return {k: jax.ShapeDtypeStruct(shape, dtype)
            for k, (shape, dtype, _) in layout.items()}


def materialize(layout: dict, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    out = {}
    for k, (shape, dtype, kind) in layout.items():
        if kind.startswith("tokens:") or kind.startswith("ids:"):
            hi = int(kind.split(":")[1])
            out[k] = jnp.asarray(
                rng.integers(0, hi, size=shape).astype(np.int32))
        elif kind == "bool":
            out[k] = jnp.asarray(np.ones(shape, bool))
        elif kind == "pos":
            out[k] = jnp.asarray(
                rng.normal(size=shape).astype(np.float32) * 2.0)
        elif kind == "angle":
            out[k] = jnp.asarray(
                rng.uniform(0, np.pi, size=shape).astype(np.float32))
        elif kind == "zeros":
            out[k] = jnp.zeros(shape, dtype)
        else:
            out[k] = jnp.asarray(
                rng.normal(size=shape).astype(np.float32)).astype(dtype)
    return out


# ---------------------------------------------------------------------------
# layouts per family
# ---------------------------------------------------------------------------

def lm_train_layout(batch: int, seq: int, vocab: int) -> dict:
    return {
        "tokens": ((batch, seq), jnp.int32, f"tokens:{vocab}"),
        "targets": ((batch, seq), jnp.int32, f"tokens:{vocab}"),
    }


def lm_decode_layout(batch: int, vocab: int) -> dict:
    return {"tokens": ((batch, 1), jnp.int32, f"tokens:{vocab}")}


def lm_prefill_layout(batch: int, seq: int, vocab: int) -> dict:
    return {"tokens": ((batch, seq), jnp.int32, f"tokens:{vocab}")}


def gnn_layout(arch: str, n_nodes: int, n_edges_directed: int, d_feat: int,
               d_out: int, n_graphs: int | None = None,
               tri_cap: int | None = None, mesh_ratio: int = 16) -> dict:
    """Shared GNN input layout. n_edges_directed counts each direction."""
    e = n_edges_directed
    lay = {
        "node_feat": ((n_nodes, d_feat), jnp.float32, "float"),
        "positions": ((n_nodes, 3), jnp.float32, "pos"),
        "src": ((e,), jnp.int32, f"ids:{n_nodes}"),
        "dst": ((e,), jnp.int32, f"ids:{n_nodes}"),
        "edge_mask": ((e,), jnp.bool_, "bool"),
        "node_mask": ((n_nodes,), jnp.bool_, "bool"),
    }
    if n_graphs is not None:
        lay["graph_ids"] = ((n_nodes,), jnp.int32, f"ids:{n_graphs}")
        lay["targets"] = ((n_graphs, d_out), jnp.float32, "float")
    else:
        lay["targets"] = ((n_nodes, d_out), jnp.float32, "float")
    if arch == "dimenet":
        t = tri_cap if tri_cap is not None else 2 * e
        lay.update({
            "tri_kj": ((t,), jnp.int32, f"ids:{e}"),
            "tri_ji": ((t,), jnp.int32, f"ids:{e}"),
            "tri_mask": ((t,), jnp.bool_, "bool"),
            "tri_angle": ((t,), jnp.float32, "angle"),
        })
    if arch == "graphcast":
        m = max(n_nodes // mesh_ratio, 4)
        me = 4 * m
        lay.update({
            "mesh_pos": ((m, 3), jnp.float32, "pos"),
            "g2m_src": ((n_nodes,), jnp.int32, f"ids:{n_nodes}"),
            "g2m_dst": ((n_nodes,), jnp.int32, f"ids:{m}"),
            "g2m_mask": ((n_nodes,), jnp.bool_, "bool"),
            "mesh_src": ((me,), jnp.int32, f"ids:{m}"),
            "mesh_dst": ((me,), jnp.int32, f"ids:{m}"),
            "mesh_mask": ((me,), jnp.bool_, "bool"),
            "m2g_src": ((n_nodes,), jnp.int32, f"ids:{m}"),
            "m2g_dst": ((n_nodes,), jnp.int32, f"ids:{n_nodes}"),
            "m2g_mask": ((n_nodes,), jnp.bool_, "bool"),
        })
    return lay


def mind_train_layout(batch: int, hist_len: int, n_items: int) -> dict:
    return {
        "hist": ((batch, hist_len), jnp.int32, f"ids:{n_items}"),
        "hist_mask": ((batch, hist_len), jnp.bool_, "bool"),
        "target": ((batch,), jnp.int32, f"ids:{n_items}"),
    }


def mind_serve_layout(batch: int, hist_len: int, n_items: int,
                      n_cands: int) -> dict:
    return {
        "hist": ((batch, hist_len), jnp.int32, f"ids:{n_items}"),
        "hist_mask": ((batch, hist_len), jnp.bool_, "bool"),
        "cands": ((batch, n_cands), jnp.int32, f"ids:{n_items}"),
    }


def mind_retrieval_layout(hist_len: int, n_items: int,
                          n_cands: int) -> dict:
    return {
        "hist": ((1, hist_len), jnp.int32, f"ids:{n_items}"),
        "hist_mask": ((1, hist_len), jnp.bool_, "bool"),
        "cands": ((n_cands,), jnp.int32, f"ids:{n_items}"),
    }


# ---------------------------------------------------------------------------
# Coherent small-graph batches (smoke tests need real geometry/topology)
# ---------------------------------------------------------------------------

def coherent_gnn_batch(arch: str, n_nodes: int, avg_deg: int, d_feat: int,
                       d_out: int, seed: int = 0,
                       n_graphs: int | None = None) -> dict:
    """Small but *valid* graph batch: consistent edges, triplets, meshes."""
    rng = np.random.default_rng(seed)
    pos = rng.normal(size=(n_nodes, 3)).astype(np.float32) * 2.0
    # kNN-ish random graph
    m = n_nodes * avg_deg // 2
    src = rng.integers(0, n_nodes, m)
    dst = rng.integers(0, n_nodes, m)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    src2 = np.concatenate([src, dst]).astype(np.int32)
    dst2 = np.concatenate([dst, src]).astype(np.int32)
    e = src2.shape[0]
    batch = {
        "node_feat": jnp.asarray(
            rng.normal(size=(n_nodes, d_feat)).astype(np.float32)),
        "positions": jnp.asarray(pos),
        "src": jnp.asarray(src2),
        "dst": jnp.asarray(dst2),
        "edge_mask": jnp.ones((e,), bool),
        "node_mask": jnp.ones((n_nodes,), bool),
    }
    if n_graphs is not None:
        gid = (np.arange(n_nodes) * n_graphs // n_nodes).astype(np.int32)
        batch["graph_ids"] = jnp.asarray(gid)
        batch["targets"] = jnp.asarray(
            rng.normal(size=(n_graphs, d_out)).astype(np.float32))
    else:
        batch["targets"] = jnp.asarray(
            rng.normal(size=(n_nodes, d_out)).astype(np.float32))
    if arch == "dimenet":
        # Real triplets: (k→j) feeding (j→i), capped.
        by_dst: dict[int, list[int]] = {}
        for eid, dd in enumerate(dst2):
            by_dst.setdefault(int(dd), []).append(eid)
        tk, tj, ang = [], [], []
        cap = 4 * e
        for eid_ji in range(e):
            j = int(src2[eid_ji])
            for eid_kj in by_dst.get(j, [])[:4]:
                if int(src2[eid_kj]) == int(dst2[eid_ji]):
                    continue
                v1 = pos[int(src2[eid_kj])] - pos[j]
                v2 = pos[int(dst2[eid_ji])] - pos[j]
                cos = np.dot(v1, v2) / (np.linalg.norm(v1)
                                        * np.linalg.norm(v2) + 1e-9)
                tk.append(eid_kj)
                tj.append(eid_ji)
                ang.append(np.arccos(np.clip(cos, -1, 1)))
                if len(tk) >= cap:
                    break
            if len(tk) >= cap:
                break
        t = max(len(tk), 1)
        tri_kj = np.zeros(cap, np.int32)
        tri_ji = np.zeros(cap, np.int32)
        tri_angle = np.zeros(cap, np.float32)
        tri_mask = np.zeros(cap, bool)
        tri_kj[:t] = tk[:t] or [0]
        tri_ji[:t] = tj[:t] or [0]
        tri_angle[:t] = ang[:t] or [0.0]
        tri_mask[:len(tk)] = True
        batch.update({
            "tri_kj": jnp.asarray(tri_kj), "tri_ji": jnp.asarray(tri_ji),
            "tri_angle": jnp.asarray(tri_angle),
            "tri_mask": jnp.asarray(tri_mask),
        })
    if arch == "graphcast":
        mesh_n = max(n_nodes // 16, 4)
        assign = (np.arange(n_nodes) * mesh_n // n_nodes).astype(np.int32)
        mesh_pos = np.stack([pos[assign == i].mean(0) if (assign == i).any()
                             else np.zeros(3) for i in range(mesh_n)])
        me = 4 * mesh_n
        ms = rng.integers(0, mesh_n, me).astype(np.int32)
        md = rng.integers(0, mesh_n, me).astype(np.int32)
        batch.update({
            "mesh_pos": jnp.asarray(mesh_pos.astype(np.float32)),
            "g2m_src": jnp.asarray(np.arange(n_nodes, dtype=np.int32)),
            "g2m_dst": jnp.asarray(assign),
            "g2m_mask": jnp.ones((n_nodes,), bool),
            "mesh_src": jnp.asarray(ms), "mesh_dst": jnp.asarray(md),
            "mesh_mask": jnp.ones((me,), bool),
            "m2g_src": jnp.asarray(assign),
            "m2g_dst": jnp.asarray(np.arange(n_nodes, dtype=np.int32)),
            "m2g_mask": jnp.ones((n_nodes,), bool),
        })
    return batch
