"""§Perf hillclimb measurements: paper-faithful baseline vs optimized,
reconstructed per-device roofline terms for the three chosen cells.

    PYTHONPATH=src python -m benchmarks.hillclimb --pick decode|query|train

Each pick prints before/after terms; EXPERIMENTS.md §Perf records the
hypothesis → change → measure → verdict log.
"""
from __future__ import annotations

import argparse
import json

from benchmarks.roofline import (_analysis_costs, PEAK_FLOPS, HBM_BW,
                                 LINK_BW)


def _terms(c: dict) -> str:
    return (f"comp={c['flops'] / PEAK_FLOPS * 1e3:9.3f}ms "
            f"mem={c['bytes'] / HBM_BW * 1e3:9.3f}ms "
            f"coll={c['coll'] / LINK_BW * 1e3:9.3f}ms")


def pick_decode() -> None:
    """gemma2-9b decode_32k: ring-buffer caches for the 21 local layers."""
    for shape in ("decode_32k", "long_500k"):
        # baseline: alternating local/global, full-length caches
        c1 = _analysis_costs("gemma2-9b", shape, 1)
        c2 = _analysis_costs("gemma2-9b", shape, 2)
        c3 = _analysis_costs("gemma2-9b", shape, 3)
        loc = {k: c3[k] - c2[k] for k in c1}
        glob = {k: c2[k] - c1[k] for k in c1}
        base = {k: c1[k] + 20 * loc[k] + 21 * glob[k] for k in c1}
        # optimized: ring windows (paired scan), reconstruct over pairs
        r2 = _analysis_costs("gemma2-9b", shape, 2,
                             {"ring_local": True})
        r4 = _analysis_costs("gemma2-9b", shape, 4,
                             {"ring_local": True})
        pair = {k: r4[k] - r2[k] for k in r2}
        opt = {k: r2[k] + 20 * pair[k] for k in r2}
        print(f"gemma2-9b {shape} BASELINE: {_terms(base)}")
        print(f"gemma2-9b {shape} RING:     {_terms(opt)}")
        for k in base:
            print(f"  {k}: {base[k]:.3e} -> {opt[k]:.3e} "
                  f"({opt[k] / max(base[k], 1e-9):.2%})")

    # mixtral: every layer is SWA → every cache becomes a 4k ring
    for shape in ("decode_32k", "long_500k"):
        c1 = _analysis_costs("mixtral-8x22b", shape, 1)
        c2 = _analysis_costs("mixtral-8x22b", shape, 2)
        lay = {k: c2[k] - c1[k] for k in c1}
        base = {k: c1[k] + 55 * lay[k] for k in c1}
        r1 = _analysis_costs("mixtral-8x22b", shape, 1,
                             {"ring_local": True})
        r2 = _analysis_costs("mixtral-8x22b", shape, 2,
                             {"ring_local": True})
        rlay = {k: r2[k] - r1[k] for k in r1}
        opt = {k: r1[k] + 55 * rlay[k] for k in r1}
        print(f"mixtral-8x22b {shape} BASELINE: {_terms(base)}")
        print(f"mixtral-8x22b {shape} RING:     {_terms(opt)}")
        for k in base:
            print(f"  {k}: {base[k]:.3e} -> {opt[k]:.3e} "
                  f"({opt[k] / max(base[k], 1e-9):.2%})")


def pick_query() -> None:
    """batchhl query_1k: replicate-graph layout (already dry-run cells)."""
    for tag in ("query_1k", "query_1k_repl"):
        r = json.load(open(f"experiments/dryrun/batchhl__{tag}__single.json"))
        c = {"flops": r["cost"]["flops"],
             "bytes": r["cost"]["bytes accessed"],
             "coll": r["collectives"]["total_bytes"]}
        print(f"batchhl {tag}: {_terms(c)}  (per BiBFS wave)")


def pick_train(overrides: dict | None = None, label: str = "BASELINE"):
    """minitron-4b train_4k: the collective-bound train cell."""
    c1 = _analysis_costs("minitron-4b", "train_4k", 1, overrides)
    c2 = _analysis_costs("minitron-4b", "train_4k", 2, overrides)
    lay = {k: c2[k] - c1[k] for k in c1}
    total = {k: c1[k] + 31 * lay[k] for k in c1}
    print(f"minitron-4b train_4k {label}: {_terms(total)}")
    print(f"  base(no-layers)={_terms({k: c1[k] - lay[k] for k in c1})}")
    print(f"  per-layer      ={_terms(lay)}")
    return total


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pick", default="all",
                    choices=["decode", "query", "train", "all"])
    args = ap.parse_args()
    if args.pick in ("query", "all"):
        pick_query()
    if args.pick in ("decode", "all"):
        pick_decode()
    if args.pick in ("train", "all"):
        pick_train()


if __name__ == "__main__":
    main()
