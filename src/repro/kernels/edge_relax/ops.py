"""Jit'd wrappers: tiled Pallas edge relaxation with jnp fallback.

`BlockedGraph` carries the one-off destination-block tiling, organized as
`shards` contiguous block_v-aligned vertex shards (leading [S] axis on every
tile array; S=1 is the classic unsharded tiling). Tile rows are
[S, NR, BE]: without a `block_e` cap one row per destination block
(NR = NB), with one a tuned cap that chunks oversized blocks into several
consecutive rows (`rowblk_t` names each row's block — see
`kernel.block_edges_topology`). The tiling is purely topological
(src / local-dst / original-slot permutation): per-sweep edge validity —
which churns with every batch update and with the repair
boundary/interior masks — is re-tiled on device with a single gather
through `perm_t`, so re-tiling on host is needed only when topology slots
change (insertions rewrite src/dst), not per wave and not per deletion.
Because no destination block straddles a shard boundary, sweep results are
bit-identical for every S — the shard axis only shapes the launch grid
(and, under a mesh, which slice a device owns). `core/engine.py` owns the
cache; this module owns the kernel launch.

`SortedGraph` is the second prepared representation the autotuner can
pick (`impl="sorted"`): the kept edge slots fully sorted by destination.
Its sweep is the same math lowered through XLA's sorted segment-min — a
compiled executable on every platform, where the Pallas kernel runs
interpret-mode off-TPU. Besides the sorted-reduction lowering it sweeps
only the *occupied* slots (the jnp reference sweeps every capacity slot),
which is where the measured win over the reference comes from on
slack-provisioned serving snapshots.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.edge_relax import kernel, ref


@partial(jax.tree_util.register_dataclass,
         data_fields=("src_t", "dstloc_t", "valid_t", "perm_t", "slot_t",
                      "rowblk_t"),
         meta_fields=("n", "block_v", "nb", "chunked"))
@dataclasses.dataclass(frozen=True)
class BlockedGraph:
    src_t: jax.Array     # int32[S, NR, BE] source vertex per tile slot
    dstloc_t: jax.Array  # int32[S, NR, BE] destination local to the block
    valid_t: jax.Array   # int32[S, NR, BE] validity baked at prepare time
    perm_t: jax.Array    # int32[S, NR, BE] original edge-slot index
    slot_t: jax.Array    # int32[S, NR, BE] 1 on real slots, 0 on padding
    rowblk_t: jax.Array  # int32[S, NR] local destination block of each row
    n: int
    block_v: int
    nb: int              # destination blocks per shard (NR >= nb)
    chunked: bool        # some destination block spans several tile rows
    # `chunked` is recorded at prepare time from the pre-shard row count:
    # post-shard shapes cannot distinguish a chunked tiling whose extra
    # rows fit inside a short last shard (NR_loc == nb_loc) from an
    # unchunked one, and skipping the row fold there drops relaxations.

    @property
    def shards(self) -> int:
        """Vertex-shard count S of the tiling (leading tile axis)."""
        return self.src_t.shape[0]

    def tile_mask(self, edge_mask: jax.Array) -> jax.Array:
        """Re-tile a per-edge mask (original slot order) on device."""
        if edge_mask.shape[0] == 0:  # zero-capacity graph: all-pad tiles
            return jnp.zeros_like(self.slot_t)
        return jnp.where(self.slot_t != 0,
                         edge_mask[self.perm_t], False).astype(jnp.int32)

    def tile_w(self, w: jax.Array | None) -> jax.Array:
        """Re-tile per-edge weights (original slot order) on device.

        `w=None` means the unweighted metric: slot_t doubles as the unit
        weight tile (1 on real slots, 0 on padding — padding is masked to
        inf anyway). Weights churn with re-weight batches the way validity
        churns with deletions, so they ride the same stored permutation and
        never force a host-side re-tile.
        """
        if w is None or w.shape[0] == 0:
            return self.slot_t
        return jnp.where(self.slot_t != 0, w[self.perm_t], 0).astype(jnp.int32)

    def tile_plane(self, plane: jax.Array, fill) -> jax.Array:
        """Pad + reshape a per-vertex plane [V] to dst tiles [S, NB, BV]."""
        s = self.src_t.shape[0]
        npad = s * self.nb * self.block_v
        padded = jnp.full((npad,), fill, plane.dtype).at[:self.n].set(plane)
        return padded.reshape(s, self.nb, self.block_v)

    def tile_plane_rows(self, plane: jax.Array, fill) -> jax.Array:
        """Per-vertex plane [V] → per-*row* dst tiles [S, NR, BV].

        The chunked kernel grid walks tile rows, so per-destination data
        (hub flags) is gathered out to one tile per row; rows of the same
        block share the block's tile. Collapses to `tile_plane` when the
        tiling is unchunked.
        """
        blocks = self.tile_plane(plane, fill)
        if not self.chunked:
            return blocks
        return jnp.take_along_axis(blocks, self.rowblk_t[..., None], axis=1)


@partial(jax.tree_util.register_dataclass,
         data_fields=("src_r", "dstg_r", "perm_r", "slot_r", "rowblk_r",
                      "adj"),
         meta_fields=("n", "fblock", "nbf", "nrows", "rows_cap"))
@dataclasses.dataclass(frozen=True)
class FrontierTiles:
    """The third prepared representation: change-propagation row tiling.

    Groups the kept edge slots into destination-block rows (the same
    host-side tiling `BlockedGraph` uses, at its own — typically finer —
    block size `fblock`), plus the block-adjacency matrix that propagates
    an active frontier one tile-neighbourhood per wave. A masked sweep
    gathers only the rows of active destination blocks through a
    static-size index vector (`jnp.nonzero(size=rows_cap,
    fill_value=nrows)` — the ragged-segment/padding shape discipline, so
    shapes stay static under jit) and scatter-mins their candidates into
    the key plane; row `nrows` is an all-padding sentinel that absorbs
    the fill slots as no-ops. Backend-independent: all three sweep impls
    (jnp, sorted, kernel) share this masked path and fall back to their
    own full sweep — bit-identically — when the frontier densifies past
    `rows_cap` (see DESIGN.md §10).
    """
    src_r: jax.Array     # int32[NR+1, BE] source vertex (row NR: sentinel)
    dstg_r: jax.Array    # int32[NR+1, BE] global destination vertex
    perm_r: jax.Array    # int32[NR+1, BE] original edge-slot index
    slot_r: jax.Array    # int32[NR+1, BE] 1 on real slots, 0 on padding
    rowblk_r: jax.Array  # int32[NR] destination block per row (nbf on
                         # bucket-padding rows: the never-active sentinel)
    adj: jax.Array       # bool[NBf, NBf] block u holds an edge into block v
    n: int
    fblock: int          # frontier block size (vertices per block)
    nbf: int             # number of frontier blocks = ceil(n / fblock)
    nrows: int           # tile rows NR, bucketed to a multiple of 64 so
                         # shapes stay trace-stable across edge churn
                         # (sentinel gather row lives at index NR)
    rows_cap: int        # masked-sweep row budget (density threshold)

    def propagate(self, front: jax.Array) -> jax.Array:
        """Blocks reachable in one wave from changed blocks `front` [NBf].

        active[bv] = ∃ bu: front[bu] ∧ adj[bu, bv] — every destination
        block that receives an edge from a changed block must relax this
        wave; all others provably cannot improve (DESIGN.md §10).
        """
        return jnp.any(self.adj & front[:, None], axis=0)

    def changed_blocks(self, changed_v: jax.Array) -> jax.Array:
        """Per-vertex changed flags [..., V] → per-block flags [..., NBf]."""
        pad = self.nbf * self.fblock - self.n
        lead = changed_v.shape[:-1]
        padded = jnp.concatenate(
            [changed_v, jnp.zeros(lead + (pad,), changed_v.dtype)], axis=-1)
        return jnp.any(padded.reshape(lead + (self.nbf, self.fblock)),
                       axis=-1)

    def active_rows(self, active_blocks: jax.Array) -> jax.Array:
        """Active-block flags [NBf] → tile-row flags [NR].

        Bucket-padding rows carry `rowblk = nbf`, which indexes the
        appended always-False slot — they never activate.
        """
        never = jnp.zeros((1,), dtype=active_blocks.dtype)
        return jnp.concatenate([active_blocks, never])[self.rowblk_r]

    def gather(self, ridx: jax.Array):
        """Materialize the rows named by `ridx` (static size, sentinel-
        filled): (src [K, BE], dst-global [K, BE], perm [K, BE],
        slot [K, BE] bool)."""
        return (self.src_r[ridx], self.dstg_r[ridx], self.perm_r[ridx],
                self.slot_r[ridx] != 0)


def prepare_frontier(src, dst, keep, n: int, fblock: int = 64,
                     block_e: int | None = 128,
                     threshold: float = 0.25) -> FrontierTiles:
    """Build the change-propagation tiling (host sync, once per topology).

    `fblock` is the frontier granularity: smaller blocks track a tight
    batch footprint more precisely but grow the adjacency matrix
    (NBf² bits) and the row count. `block_e` caps row width the way the
    kernel tiling's block_e does (oversized blocks chunk into several
    rows), keeping the masked gather's [rows_cap, BE] working set small
    on power-law hub blocks. `threshold` is the density-fallback knob:
    the masked sweep runs while the active rows fit within
    ceil(threshold · NR); denser frontiers fall back to the full sweep
    (autotunable — `core/autotune.py:tune_frontier_threshold`).
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    keep = np.asarray(keep, bool)
    src_t, dstloc_t, perm_t, slot_t, rowblk, fb = kernel.block_edges_topology(
        src, dst, keep, n, fblock, block_e)
    nr, be = src_t.shape
    nbf = -(-n // fb)
    dstg_t = np.where(slot_t != 0, rowblk[:, None] * fb + dstloc_t, 0)
    # Bucket the row count to a multiple of 64: the row arrays' shapes
    # (and rows_cap below) are jit-trace constants, so letting NR drift
    # with every inserted edge would retrace the whole update per tick —
    # a >1s spike on the serving path. Bucket-padding rows are all
    # padding slots with rowblk = nbf (the always-inactive sentinel
    # block in `active_rows`). The sentinel gather row still lives at
    # index NR (= the bucketed count).
    nr_b = max(64, -(-nr // 64) * 64)
    pad_rows = np.zeros((nr_b - nr + 1, be), np.int32)
    rowblk_b = np.concatenate(
        [rowblk, np.full(nr_b - nr, nbf, np.int32)])
    adj = np.zeros((nbf, nbf), bool)
    if keep.any():
        adj[src[keep] // fb, dst[keep] // fb] = True
    rows_cap = max(1, min(nr_b, int(np.ceil(nr_b * threshold))))
    return FrontierTiles(
        jnp.asarray(np.concatenate([src_t, pad_rows])),
        jnp.asarray(np.concatenate([dstg_t, pad_rows])),
        jnp.asarray(np.concatenate([perm_t, pad_rows])),
        jnp.asarray(np.concatenate([slot_t, pad_rows])),
        jnp.asarray(rowblk_b), jnp.asarray(adj),
        n, fb, nbf, nr_b, rows_cap)


@partial(jax.tree_util.register_dataclass,
         data_fields=("src_s", "dst_s", "perm_s"),
         meta_fields=("n",))
@dataclasses.dataclass(frozen=True)
class SortedGraph:
    """Kept edge slots fully sorted by destination (the `sorted` impl).

    `perm_s` maps each sorted position back to its original edge slot, so
    per-sweep masks re-tile with one gather — the same contract as
    `BlockedGraph.tile_mask`. Sorting is total (by dst vertex, not dst
    block), which is what lets the sweep lower through
    `segment_min(indices_are_sorted=True)`.
    """
    src_s: jax.Array   # int32[M] source vertex, dst-sorted order
    dst_s: jax.Array   # int32[M] destination vertex, ascending
    perm_s: jax.Array  # int32[M] original edge-slot index
    n: int


def prepare(src, dst, valid, n: int, block_v: int = 512,
            shards: int = 1, block_e: int | None = None) -> BlockedGraph:
    """Tile every edge slot; bake `valid` into valid_t (legacy entry)."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    valid = np.asarray(valid, bool)
    src_t, dstloc_t, perm_t, slot_t, rowblk, bv = kernel.block_edges_topology(
        src, dst, np.ones(len(src), bool), n, block_v, block_e)
    valid_t = (np.where(slot_t != 0, valid[perm_t].astype(np.int32), 0)
               if len(valid) else np.zeros_like(slot_t))
    nb = -(-n // bv)
    chunked = len(rowblk) != nb
    rowblk_t, nb_loc, src_t, dstloc_t, valid_t, perm_t, slot_t = \
        kernel.shard_tiling(shards, nb, rowblk, src_t, dstloc_t,
                            valid_t.astype(np.int32), perm_t, slot_t)
    return BlockedGraph(jnp.asarray(src_t), jnp.asarray(dstloc_t),
                        jnp.asarray(valid_t), jnp.asarray(perm_t),
                        jnp.asarray(slot_t), jnp.asarray(rowblk_t),
                        n, bv, nb_loc, chunked)


def prepare_topology(src, dst, keep, n: int, block_v: int = 512,
                     shards: int = 1,
                     block_e: int | None = None) -> BlockedGraph:
    """Tile only the `keep` slots (host sync; amortized by core/engine.py).

    `keep` should be the currently-occupied slots: future deletions only
    flip validity (handled per sweep via `tile_mask`), while insertions
    rewrite src/dst and therefore force a fresh prepare anyway.

    `shards` splits the destination-block tiling into that many contiguous
    vertex shards (the leading [S] tile axis — see `kernel.shard_tiling`);
    `block_e` caps the tile-row width, chunking oversized destination
    blocks into several rows. Results are bit-identical for every S and
    every block_e — both are launch-structure knobs the autotuner sweeps.

    The returned tiling sets `valid_t` to slot *occupancy*, not edge
    validity — it must only be consumed through `relax_sweep`, which
    re-tiles the caller's current per-edge mask via `perm_t` every wave.
    Feeding it to the legacy `edge_relax` (which trusts `valid_t`) would
    treat edges deleted after prepare time as still present.
    """
    src_t, dstloc_t, perm_t, slot_t, rowblk, bv = kernel.block_edges_topology(
        np.asarray(src), np.asarray(dst), np.asarray(keep, bool), n, block_v,
        block_e)
    nb = -(-n // bv)
    chunked = len(rowblk) != nb
    rowblk_t, nb_loc, src_t, dstloc_t, perm_t, slot_t = kernel.shard_tiling(
        shards, nb, rowblk, src_t, dstloc_t, perm_t, slot_t)
    return BlockedGraph(jnp.asarray(src_t), jnp.asarray(dstloc_t),
                        jnp.asarray(slot_t), jnp.asarray(perm_t),
                        jnp.asarray(slot_t), jnp.asarray(rowblk_t),
                        n, bv, nb_loc, chunked)


def prepare_sorted(src, dst, keep, n: int) -> SortedGraph:
    """Sort the kept edge slots by destination (host sync, once per
    topology — the `sorted` twin of `prepare_topology`)."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    keep = np.asarray(keep, bool)
    idx = np.flatnonzero(keep)
    order = np.argsort(dst[idx], kind="stable")
    perm = idx[order].astype(np.int32)
    return SortedGraph(jnp.asarray(src[perm]), jnp.asarray(dst[perm]),
                       jnp.asarray(perm), n)


def edge_relax(keys: jax.Array, bg: BlockedGraph, step,
               use_pallas: bool | None = None) -> jax.Array:
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    interpret = jax.default_backend() != "tpu"
    rowblk_t = bg.rowblk_t if bg.chunked else None
    if use_pallas or interpret is False:
        return kernel.edge_relax_pallas(keys, bg.src_t, bg.dstloc_t,
                                        bg.valid_t, step, bg.n, bg.block_v,
                                        interpret=interpret,
                                        rowblk_t=rowblk_t, nb=bg.nb)
    # jnp fallback on the tiled representation (same math, XLA segment_min).
    s, nr, _ = bg.src_t.shape
    blk = bg.rowblk_t + (jnp.arange(s) * bg.nb)[:, None]      # global block
    flat_dst = bg.dstloc_t + blk[..., None] * bg.block_v
    return ref.edge_relax(keys, bg.src_t.reshape(-1), flat_dst.reshape(-1),
                          bg.valid_t.reshape(-1) != 0, step,
                          s * bg.nb * bg.block_v)[:bg.n]


def relax_sweep(keys: jax.Array, bg: BlockedGraph, edge_mask: jax.Array,
                step, inf, clear_bit=0,
                hub: jax.Array | None = None,
                w: jax.Array | None = None) -> jax.Array:
    """Generalized relaxation sweep on the tiled graph (Pallas path).

    cand[v] = min over edges (u, v) with edge_mask of
        extend(keys[u]) = clear_bit-cleared-if-hub[v]
                          sat(keys[u] + step·w(u,v), inf)

    `edge_mask` and `w` are in original edge-slot order (length = edge
    capacity); `w=None` is the unweighted metric (w ≡ 1 on real slots).
    `hub` is a per-vertex bool plane [V] (or None for plain relaxation).
    Runs interpret-mode Pallas off-TPU so parity tests exercise the same
    kernel that runs compiled on TPU.
    """
    mask_t = bg.tile_mask(edge_mask)
    w_t = bg.tile_w(w)
    if hub is None:
        s, nr, _ = bg.src_t.shape
        hub_t = jnp.zeros((s, nr, bg.block_v), jnp.int32)
    else:
        hub_t = bg.tile_plane_rows(hub.astype(jnp.int32), 0)
    interpret = jax.default_backend() != "tpu"
    rowblk_t = bg.rowblk_t if bg.chunked else None
    return kernel.relax_sweep_pallas(keys, hub_t, bg.src_t, bg.dstloc_t,
                                     mask_t, w_t, step, inf, clear_bit,
                                     bg.n, bg.block_v, interpret=interpret,
                                     rowblk_t=rowblk_t, nb=bg.nb)


def relax_sweep_sorted(keys: jax.Array, sg: SortedGraph,
                       edge_mask: jax.Array, step, inf, clear_bit=0,
                       hub: jax.Array | None = None,
                       w: jax.Array | None = None) -> jax.Array:
    """The `sorted` impl of the same sweep: compiled XLA everywhere.

    Identical math to `relax_sweep` over the identical edge multiset —
    gather, weighted saturating extend, mask, min-reduce by destination —
    so results are bit-identical to both the kernel path and the jnp
    reference (`tests/test_kernel_tuning.py` pins all three). The
    reduction is a `segment_min` over the destination-sorted slots with
    `indices_are_sorted=True`, and only the occupied slots participate.
    """
    mask = edge_mask[sg.perm_s]
    gathered = jnp.take(keys, sg.src_s, axis=0)
    sw = step if w is None else step * jnp.take(w, sg.perm_s, axis=0)
    s = gathered + sw
    cand = jnp.minimum(jnp.where(s < 0, inf, s), inf)
    if hub is not None:
        hub_e = jnp.take(hub, sg.dst_s, axis=0)
        cand = jnp.where(hub_e, cand & ~jnp.int32(clear_bit), cand)
    cand = jnp.where(mask, cand, inf)
    out = jax.ops.segment_min(cand, sg.dst_s, num_segments=sg.n,
                              indices_are_sorted=True)
    return jnp.minimum(out, inf)   # empty segments fill with int32-max
