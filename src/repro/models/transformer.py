"""Composable decoder-only transformer covering the five assigned LM archs.

Features (selected per-config): GQA and MLA attention, RoPE, sliding-window
and local/global-alternating attention, attn/final logit softcapping
(Gemma-2), gated (SwiGLU/GeGLU) and ungated (ReLU²) FFNs, capacity-based
top-k MoE with shared experts (Mixtral / DeepSeek-V2), scan-over-layers with
remat, flash-style chunked attention (no O(S²) buffer is ever materialised),
and KV-cache decode with sequence-sharded caches (flash-decoding semantics
via GSPMD partial-softmax collectives).

Weights are stored bf16 (configurable); matmuls accumulate in f32.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import moe as moe_lib

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # attention flavour
    attn_pattern: str = "full"       # full | swa | local_global
    window: int = 4096
    attn_softcap: float | None = None
    final_softcap: float | None = None
    # MLA (DeepSeek-V2)
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # FFN flavour
    act: str = "silu"                # silu | gelu | relu2
    gated: bool = True
    # MoE
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 2
    d_ff_expert: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    # misc
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    # attention chunking (flash-style)
    q_chunk: int = 512
    kv_chunk: int = 512
    loss_chunk: int = 512
    # analysis mode: unroll the layer stack (python loop) so
    # compiled.cost_analysis() counts every layer — used by the roofline
    # extraction, never in production (see benchmarks/roofline.py).
    unroll_layers: bool = False
    # §Perf beyond-paper optimization: sliding-window layers keep a
    # ring-buffer KV cache of `window` entries instead of the full context
    # (decode memory term ∝ cache reads; see EXPERIMENTS.md §Perf).
    ring_local: bool = False
    # §Perf: under the v2 scheme attention is data-parallel; this constraint
    # additionally spreads the batch over ('data','model') around attention
    # so the model axis doesn't idle there (train cells with batch % 256
    # == 0 only; needs a mesh context — set by lm_cell, never in CPU tests).
    attn_2d_batch: bool = False

    @property
    def params_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        c = self
        embed = c.vocab * c.d_model
        if c.use_mla:
            attn = c.d_model * (c.n_heads * (c.qk_nope_dim + c.qk_rope_dim))
            attn += c.d_model * (c.kv_lora_rank + c.qk_rope_dim)
            attn += c.kv_lora_rank * c.n_heads * (c.qk_nope_dim
                                                  + c.v_head_dim)
            attn += c.n_heads * c.v_head_dim * c.d_model
        else:
            attn = c.d_model * c.n_heads * c.d_head
            attn += 2 * c.d_model * c.n_kv_heads * c.d_head
            attn += c.n_heads * c.d_head * c.d_model
        ffn_dense = c.d_model * c.d_ff * (3 if c.gated else 2)
        if c.moe:
            ffn_moe = (c.n_experts
                       * c.d_model * c.d_ff_expert * (3 if c.gated else 2))
            ffn_moe += c.n_shared_experts * c.d_model * c.d_ff_expert * 3
            ffn_moe += c.d_model * c.n_experts  # router
            n_moe = c.n_layers - c.first_k_dense
            ffn_total = c.first_k_dense * ffn_dense + n_moe * ffn_moe
        else:
            ffn_total = c.n_layers * ffn_dense
        return embed + c.n_layers * attn + ffn_total + embed  # + lm head

    @property
    def active_params_count(self) -> int:
        """Active parameters per token (MoE: only routed-to experts)."""
        c = self
        if not c.moe:
            return self.params_count
        embed = c.vocab * c.d_model
        attn = (c.d_model * c.n_heads * c.d_head
                + 2 * c.d_model * c.n_kv_heads * c.d_head
                + c.n_heads * c.d_head * c.d_model)
        if c.use_mla:
            attn = (c.d_model * (c.n_heads * (c.qk_nope_dim + c.qk_rope_dim))
                    + c.d_model * (c.kv_lora_rank + c.qk_rope_dim)
                    + c.kv_lora_rank * c.n_heads * (c.qk_nope_dim
                                                    + c.v_head_dim)
                    + c.n_heads * c.v_head_dim * c.d_model)
        act_ffn = ((c.top_k + c.n_shared_experts)
                   * c.d_model * c.d_ff_expert * (3 if c.gated else 2))
        return embed * 2 + c.n_layers * (attn + act_ffn)


# ---------------------------------------------------------------------------
# Parameter init / shape declaration
# ---------------------------------------------------------------------------

def _dense(key, shape, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def layer_param_shapes(c: TransformerConfig, moe_layer: bool) -> dict:
    """Shapes of one layer's params (stacked under a leading L axis later)."""
    d, dt = c.d_model, c.dtype
    p: dict[str, Any] = {
        "ln_attn": ((d,), jnp.float32),
        "ln_ffn": ((d,), jnp.float32),
    }
    if c.use_mla:
        p.update({
            "wq": ((d, c.n_heads * (c.qk_nope_dim + c.qk_rope_dim)), dt),
            "wkv_a": ((d, c.kv_lora_rank + c.qk_rope_dim), dt),
            "kv_ln": ((c.kv_lora_rank,), jnp.float32),
            "wkv_b": ((c.kv_lora_rank,
                       c.n_heads * (c.qk_nope_dim + c.v_head_dim)), dt),
            "wo": ((c.n_heads * c.v_head_dim, d), dt),
        })
    else:
        p.update({
            "wq": ((d, c.n_heads * c.d_head), dt),
            "wk": ((d, c.n_kv_heads * c.d_head), dt),
            "wv": ((d, c.n_kv_heads * c.d_head), dt),
            "wo": ((c.n_heads * c.d_head, d), dt),
        })
    if moe_layer:
        e, f = c.n_experts, c.d_ff_expert
        p["router"] = ((d, e), jnp.float32)
        p["w_gate"] = ((e, d, f), dt)
        p["w_up"] = ((e, d, f), dt)
        p["w_down"] = ((e, f, d), dt)
        if c.n_shared_experts:
            fs = c.n_shared_experts * f
            p["ws_gate"] = ((d, fs), dt)
            p["ws_up"] = ((d, fs), dt)
            p["ws_down"] = ((fs, d), dt)
    else:
        p["w_gate"] = ((c.d_model, c.d_ff), dt)
        if c.gated:
            p["w_up"] = ((c.d_model, c.d_ff), dt)
        p["w_down"] = ((c.d_ff, c.d_model), dt)
    return p


def param_shapes(c: TransformerConfig) -> dict:
    """Full ShapeDtypeStruct pytree (for eval_shape / dry-run lowering)."""
    def stack(shapes: dict, n: int) -> dict:
        return {k: jax.ShapeDtypeStruct((n,) + s, d)
                for k, (s, d) in shapes.items()}

    n_moe = c.n_layers - c.first_k_dense if c.moe else 0
    n_dense = c.n_layers - n_moe
    out = {
        "embed": jax.ShapeDtypeStruct((c.vocab, c.d_model), c.dtype),
        "final_ln": jax.ShapeDtypeStruct((c.d_model,), jnp.float32),
        "lm_head": jax.ShapeDtypeStruct((c.d_model, c.vocab), c.dtype),
    }
    if n_dense:
        out["dense_layers"] = stack(layer_param_shapes(c, False), n_dense)
    if n_moe:
        out["moe_layers"] = stack(layer_param_shapes(c, True), n_moe)
    return out


def init_params(key: jax.Array, c: TransformerConfig) -> dict:
    """Real initialization (used by smoke tests / examples)."""
    shapes = param_shapes(c)
    flat, treedef = jax.tree_util.tree_flatten(
        shapes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    keys = jax.random.split(key, len(flat))
    leaves = []
    for k, s in zip(keys, flat):
        if s.dtype == jnp.float32 and len(s.shape) <= 2 and (
                s.shape[-1:] and False):
            leaves.append(jnp.ones(s.shape, s.dtype))
        elif len(s.shape) >= 2:
            scale = 1.0 / math.sqrt(s.shape[-2])
            leaves.append(_dense(k, s.shape, s.dtype, scale))
        else:
            leaves.append(jnp.ones(s.shape, s.dtype))  # norms
    return jax.tree_util.tree_unflatten(treedef, leaves)


def param_specs(c: TransformerConfig, pod: bool = False,
                scheme: str = "v2") -> dict:
    """PartitionSpec pytree.

    scheme="v1" (paper-faithful first cut, kept for §Perf baselines):
    every projection output-sharded over 'model' — misaligned with head
    boundaries when H or KV don't divide 16, which makes GSPMD emit huge
    partial-sum all-reduces inside attention and the loss (measured in
    §Perf: 51 GB/score-tensor on minitron).

    scheme="v2" (default): Megatron-style hybrid —
      * attention weights FSDP on the d_model dim only; heads stay whole,
        attention is data-parallel (no model-axis collectives inside attn);
      * FFN tensor-parallel on d_ff over 'model' (always divisible);
      * embed + lm_head vocab-parallel over 'model' (loss reduces to a
        tiny [B,S] psum instead of all-reducing full logits).
    """
    fsdp = ("pod", "data") if pod else ("data",)
    tp = "model"
    v2 = scheme == "v2"

    def dense_specs(moe_layer: bool) -> dict:
        s: dict[str, Any] = {
            "ln_attn": P(None, None),
            "ln_ffn": P(None, None),
        }
        if c.use_mla:
            s.update({
                "wq": P(None, fsdp, None) if v2 else P(None, fsdp, tp),
                "wkv_a": P(None, fsdp, None),
                "kv_ln": P(None, None),
                "wkv_b": P(None, None, None) if v2 else P(None, fsdp, tp),
                "wo": P(None, None, fsdp) if v2 else P(None, tp, fsdp),
            })
        else:
            qkv = P(None, fsdp, None) if v2 else P(None, fsdp, tp)
            s.update({
                "wq": qkv,
                "wk": qkv,
                "wv": qkv,
                "wo": P(None, None, fsdp) if v2 else P(None, tp, fsdp),
            })
        if moe_layer:
            s["router"] = P(None, fsdp, None)
            # Expert parallelism when the expert count divides the model
            # axis (deepseek: 64/16); otherwise Megatron-style expert-TP on
            # the ffn dim (mixtral: 8 experts < 16-way model axis).
            if c.n_experts % 16 == 0:
                s["w_gate"] = P(None, tp, fsdp, None)
                s["w_up"] = P(None, tp, fsdp, None)
                s["w_down"] = P(None, tp, None, fsdp)
            else:
                s["w_gate"] = P(None, None, fsdp, tp)
                s["w_up"] = P(None, None, fsdp, tp)
                s["w_down"] = P(None, None, tp, fsdp)
            if c.n_shared_experts:
                s["ws_gate"] = P(None, fsdp, tp)
                s["ws_up"] = P(None, fsdp, tp)
                s["ws_down"] = P(None, tp, fsdp)
        else:
            s["w_gate"] = P(None, fsdp, tp)
            if c.gated:
                s["w_up"] = P(None, fsdp, tp)
            s["w_down"] = P(None, tp, fsdp)
        return s

    n_moe = c.n_layers - c.first_k_dense if c.moe else 0
    out = {
        "embed": P(tp, None) if v2 else P(tp, fsdp),
        "final_ln": P(None),
        "lm_head": P(None, tp) if v2 else P(fsdp, tp),
    }
    if c.n_layers - n_moe:
        out["dense_layers"] = dense_specs(False)
    if n_moe:
        out["moe_layers"] = dense_specs(True)
    return out


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [..., S, H, D]; positions [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # [.., S, half]
    angles = angles[..., None, :]                                # [.., S, 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def _softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def _mm(x, w):
    return jnp.einsum("...d,df->...f", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_offset: jax.Array, c: TransformerConfig,
                      is_local: jax.Array, kv_len_valid: jax.Array | None,
                      scale: float | None = None) -> jax.Array:
    """Flash-style attention: scan over q- and kv-chunks, online softmax.

    q [B, Sq, H, Dq]; k [B, Skv, KV, Dq]; v [B, Skv, KV, Dv].
    q_offset: absolute position of q[0] (decode: cache length).
    is_local: scalar bool — apply the sliding window (pattern-dependent).
    kv_len_valid: [B] number of valid cache entries (decode), else None.
    Causal masking is in absolute positions. Never materialises S².
    """
    b, sq, h, dq = q.shape
    skv, kv_heads = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    groups = h // kv_heads
    scale = scale if scale is not None else 1.0 / math.sqrt(dq)

    cq = min(c.q_chunk, sq)
    ckv = min(c.kv_chunk, skv)
    nq, nkv = sq // cq, skv // ckv
    assert sq % cq == 0 and skv % ckv == 0

    q = q.reshape(b, nq, cq, kv_heads, groups, dq)
    k = k.reshape(b, nkv, ckv, kv_heads, dq)
    v = v.reshape(b, nkv, ckv, kv_heads, dv)

    def q_block(qi, q_blk):
        q_pos = q_offset + qi * cq + jnp.arange(cq)          # [cq]

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, (k_blk, v_blk) = inp
            kv_pos = kj * ckv + jnp.arange(ckv)              # [ckv]
            s = jnp.einsum("bckgd,bzkd->bkgcz", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, c.attn_softcap)
            causal = q_pos[:, None] >= kv_pos[None, :]       # [cq, ckv]
            win = q_pos[:, None] - kv_pos[None, :] < c.window
            mask = causal & jnp.where(is_local, win, True)
            mask = mask[None, None, None, :, :]              # [1,1,1,cq,ckv]
            if kv_len_valid is not None:
                valid = (kv_pos[None, :]
                         < kv_len_valid[:, None])            # [b, ckv]
                mask = mask & valid[:, None, None, None, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))      # [b,k,g,cq]
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgcz,bzkd->bkgcd", p.astype(v_blk.dtype),
                            v_blk, preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv_heads, groups, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv_heads, groups, cq), jnp.float32)
        a0 = jnp.zeros((b, kv_heads, groups, cq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nkv), (jnp.moveaxis(k, 1, 0), jnp.moveaxis(v, 1, 0))))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, 3, 1).reshape(b, cq, h, dv)  # b,cq,k,g→h

    outs = jax.lax.map(lambda args: q_block(*args),
                       (jnp.arange(nq), jnp.moveaxis(q, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, dv)
    return out.astype(jnp.bfloat16) if q.dtype == jnp.bfloat16 else out


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------

def _ring_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    n_valid: jax.Array, scale: float,
                    softcap: float | None) -> jax.Array:
    """Decode attention over a ring-buffer window cache.

    RoPE is applied at write time, and softmax is permutation-invariant, so
    slot order inside the ring is irrelevant — only slot validity matters.
    q [B,1,H,Dh]; k/v [B,W,KV,Dh]; n_valid: scalar count of live slots.
    """
    b, s, h, dh = q.shape
    w, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qr = q.reshape(b, s, kvh, g, dh)
    scores = jnp.einsum("bskgd,bwkd->bkgsw", qr, k,
                        preferred_element_type=jnp.float32) * scale
    scores = _softcap(scores, softcap)
    mask = jnp.arange(w) < n_valid                          # [w]
    scores = jnp.where(mask[None, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgsw,bwkd->bskgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, h, dh).astype(q.dtype)


def _attention_block(p: dict, x: jax.Array, c: TransformerConfig,
                     positions: jax.Array, is_local: jax.Array,
                     cache: dict | None, cache_len: jax.Array | None,
                     ring: bool = False):
    """Returns (attn_out, new_cache_entries)."""
    b, s, d = x.shape
    if c.use_mla:
        qk_dim = c.qk_nope_dim + c.qk_rope_dim
        q = _mm(x, p["wq"]).reshape(b, s, c.n_heads, qk_dim)
        q_nope, q_rope = q[..., :c.qk_nope_dim], q[..., c.qk_nope_dim:]
        q_rope = rope(q_rope, positions, c.rope_theta)
        kv_a = _mm(x, p["wkv_a"])
        c_kv = rms_norm(kv_a[..., :c.kv_lora_rank], p["kv_ln"], c.norm_eps)
        k_rope = rope(kv_a[..., None, c.kv_lora_rank:], positions,
                      c.rope_theta)                         # [b,s,1,rope]
        if cache is not None:
            c_kv = jax.lax.dynamic_update_slice(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype),
                (0, cache_len, 0))
            k_rope = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
                (0, cache_len, 0, 0))
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        skv = c_kv.shape[1]
        wkv_b = p["wkv_b"].reshape(c.kv_lora_rank, c.n_heads,
                                   c.qk_nope_dim + c.v_head_dim)
        w_uk = wkv_b[..., :c.qk_nope_dim]                   # [r, h, nope]
        w_uv = wkv_b[..., c.qk_nope_dim:]                   # [r, h, vdim]
        # Absorbed MLA: score in latent space (production decode path).
        q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk,
                           preferred_element_type=jnp.float32
                           ).astype(x.dtype)                # [b,s,h,r]
        q_eff = jnp.concatenate([q_lat, q_rope], -1)        # [b,s,h,r+rope]
        k_eff = jnp.concatenate(
            [c_kv[:, :, None, :], k_rope], -1)              # [b,skv,1,r+rope]
        # Absorbed scores equal q_nope·k_nope + q_rope·k_rope, so the scale
        # is that of the *original* head dim, not the latent dim.
        mla_scale = 1.0 / math.sqrt(c.qk_nope_dim + c.qk_rope_dim)
        attn_lat = chunked_attention(
            q_eff, k_eff, c_kv[:, :, None, :],
            cache_len if cache_len is not None else 0, c, is_local,
            (cache_len + s) * jnp.ones((b,), jnp.int32)
            if cache_len is not None else None,
            scale=mla_scale)                                # [b,s,h,r]
        out = jnp.einsum("bshr,rhv->bshv", attn_lat.astype(jnp.float32),
                         w_uv.astype(jnp.float32))
        out = out.reshape(b, s, c.n_heads * c.v_head_dim).astype(x.dtype)
        return _mm(out, p["wo"]), new_cache

    q = _mm(x, p["wq"]).reshape(b, s, c.n_heads, c.d_head)
    k = _mm(x, p["wk"]).reshape(b, s, c.n_kv_heads, c.d_head)
    v = _mm(x, p["wv"]).reshape(b, s, c.n_kv_heads, c.d_head)
    q = rope(q, positions, c.rope_theta)
    k = rope(k, positions, c.rope_theta)
    if ring:
        # ring-buffer window cache: overwrite the oldest slot
        assert cache is not None and s == 1
        slot = cache_len % c.window
        k = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        n_valid = jnp.minimum(cache_len + s, c.window)
        out = _ring_attention(q, k, v, n_valid,
                              1.0 / math.sqrt(c.d_head), c.attn_softcap)
        out = out.reshape(b, s, c.n_heads * c.d_head)
        return _mm(out, p["wo"]), {"k": k, "v": v}
    if cache is not None:
        k = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_len, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_len, 0, 0))
    new_cache = {"k": k, "v": v}
    kv_valid = ((cache_len + s) * jnp.ones((b,), jnp.int32)
                if cache_len is not None else None)
    out = chunked_attention(q, k, v,
                            cache_len if cache_len is not None else 0,
                            c, is_local, kv_valid)
    out = out.reshape(b, s, c.n_heads * c.d_head)
    return _mm(out, p["wo"]), new_cache


def _ffn_block(p: dict, x: jax.Array, c: TransformerConfig,
               moe_layer: bool) -> jax.Array:
    if moe_layer:
        out = moe_lib.moe_ffn(p, x, c)
        if c.n_shared_experts:
            g = _act(_mm(x, p["ws_gate"]), c.act)
            out = out + _mm(g * _mm(x, p["ws_up"]), p["ws_down"])
        return out
    g = _act(_mm(x, p["w_gate"]), c.act)
    h = g * _mm(x, p["w_up"]) if c.gated else g
    return _mm(h, p["w_down"])


def _layer(p: dict, x: jax.Array, c: TransformerConfig, positions, is_local,
           moe_layer: bool, cache=None, cache_len=None, ring: bool = False):
    a_in = rms_norm(x, p["ln_attn"], c.norm_eps)
    if c.attn_2d_batch and cache is None:
        a_in = jax.lax.with_sharding_constraint(
            a_in, P(("data", "model"), None, None))
    a, new_cache = _attention_block(p, a_in, c, positions, is_local, cache,
                                    cache_len, ring=ring)
    if c.attn_2d_batch and cache is None:
        a = jax.lax.with_sharding_constraint(a, P(("data",), None, None))
    x = x + a
    x = x + _ffn_block(p, rms_norm(x, p["ln_ffn"], c.norm_eps), c, moe_layer)
    return x, new_cache


def _is_local_flags(c: TransformerConfig, n: int, offset: int) -> jax.Array:
    if c.attn_pattern == "swa":
        return jnp.ones((n,), bool)
    if c.attn_pattern == "local_global":
        return (jnp.arange(offset, offset + n) % 2) == 0
    return jnp.zeros((n,), bool)


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def forward(params: dict, tokens: jax.Array, c: TransformerConfig,
            return_hidden: bool = False) -> jax.Array:
    """Training / prefill forward. tokens [B, S] → logits [B, S, vocab]
    (or final hidden states when return_hidden)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * jnp.asarray(math.sqrt(c.d_model), x.dtype)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    n_moe = c.n_layers - c.first_k_dense if c.moe else 0
    n_dense = c.n_layers - n_moe

    def run_stack(x, stack, n, offset, moe_layer):
        flags = _is_local_flags(c, n, offset)

        def body(x, inp):
            layer_p, flag = inp
            out, _ = _layer(layer_p, x, c, positions, flag, moe_layer)
            return out, None

        if c.unroll_layers:
            for i in range(n):
                layer_p = jax.tree.map(lambda a: a[i], stack)
                x, _ = jax.checkpoint(body)(x, (layer_p, flags[i]))
            return x
        x, _ = jax.lax.scan(jax.checkpoint(body), x, (stack, flags))
        return x

    if n_dense:
        x = run_stack(x, params["dense_layers"], n_dense, 0, False)
    if n_moe:
        x = run_stack(x, params["moe_layers"], n_moe, n_dense, True)

    x = rms_norm(x, params["final_ln"], c.norm_eps)
    if return_hidden:
        return x
    logits = _mm(x, params["lm_head"])
    return _softcap(logits, c.final_softcap)


def chunked_loss(params: dict, tokens: jax.Array, targets: jax.Array,
                 c: TransformerConfig) -> jax.Array:
    """Cross-entropy over seq chunks — avoids a [B,S,vocab] logits buffer."""
    hidden = forward(params, tokens, c, return_hidden=True)
    b, s, d = hidden.shape
    ck = min(c.loss_chunk, s)
    nchunk = s // ck
    hidden = hidden.reshape(b, nchunk, ck, d)
    targets = targets.reshape(b, nchunk, ck)

    def step(acc, inp):
        h, t = inp                                          # [b,ck,d],[b,ck]
        logits = _softcap(_mm(h, params["lm_head"]), c.final_softcap)
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], -1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32),
                            (jnp.moveaxis(hidden, 1, 0),
                             jnp.moveaxis(targets, 1, 0)))
    return total / (b * s)


# ---------------------------------------------------------------------------
# Decode (serve) path
# ---------------------------------------------------------------------------

def cache_shapes(c: TransformerConfig, batch: int, max_len: int) -> dict:
    """ShapeDtypeStruct pytree of the KV cache (per layer stack).

    With ring_local, sliding-window layers hold `window` slots instead of
    `max_len` (ring buffer): swa → every layer; local_global → the local
    half of each (local, global) pair."""
    n_moe = c.n_layers - c.first_k_dense if c.moe else 0
    n_dense = c.n_layers - n_moe

    def one(n, length):
        if c.use_mla:
            return {
                "c_kv": jax.ShapeDtypeStruct(
                    (n, batch, length, c.kv_lora_rank), c.dtype),
                "k_rope": jax.ShapeDtypeStruct(
                    (n, batch, length, 1, c.qk_rope_dim), c.dtype),
            }
        return {
            "k": jax.ShapeDtypeStruct(
                (n, batch, length, c.n_kv_heads, c.d_head), c.dtype),
            "v": jax.ShapeDtypeStruct(
                (n, batch, length, c.n_kv_heads, c.d_head), c.dtype),
        }

    if c.ring_local and c.attn_pattern == "swa":
        w = min(c.window, max_len)
        out = {}
        if n_dense:
            out["dense"] = one(n_dense, w)
        if n_moe:
            out["moe"] = one(n_moe, w)
        return out
    if (c.ring_local and c.attn_pattern == "local_global"
            and not c.moe and c.n_layers % 2 == 0):
        w = min(c.window, max_len)
        return {"dense_local": one(c.n_layers // 2, w),
                "dense_global": one(c.n_layers // 2, max_len)}
    out = {}
    if n_dense:
        out["dense"] = one(n_dense, max_len)
    if n_moe:
        out["moe"] = one(n_moe, max_len)
    return out


def cache_specs(c: TransformerConfig, pod: bool = False) -> dict:
    """KV cache sharded over sequence (flash-decoding) + kv heads."""
    seq_ax = ("pod", "data") if pod else ("data",)
    n_moe = c.n_layers - c.first_k_dense if c.moe else 0

    def one():
        if c.use_mla:
            return {"c_kv": P(None, None, seq_ax, "model"),
                    "k_rope": P(None, None, seq_ax, None, None)}
        return {"k": P(None, None, seq_ax, "model", None),
                "v": P(None, None, seq_ax, "model", None)}
    out = {}
    if c.n_layers - n_moe:
        out["dense"] = one()
    if n_moe:
        out["moe"] = one()
    return out


def decode_step(params: dict, cache: dict, tokens: jax.Array,
                cache_len: jax.Array, c: TransformerConfig):
    """One decode step: tokens [B, 1] → (logits [B, vocab], new cache)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x * jnp.asarray(math.sqrt(c.d_model), x.dtype)
    positions = jnp.broadcast_to(cache_len + jnp.arange(s), (b, s))

    n_moe = c.n_layers - c.first_k_dense if c.moe else 0
    n_dense = c.n_layers - n_moe
    new_cache = {}

    ring_all = c.ring_local and c.attn_pattern == "swa"
    paired = (c.ring_local and c.attn_pattern == "local_global"
              and not c.moe and c.n_layers % 2 == 0)

    def run_stack(x, stack, layer_cache, n, offset, moe_layer,
                  ring: bool = False):
        flags = _is_local_flags(c, n, offset)

        def body(x, inp):
            layer_p, flag, lc = inp
            out, nc = _layer(layer_p, x, c, positions, flag, moe_layer,
                             cache=lc, cache_len=cache_len, ring=ring)
            return out, nc

        if c.unroll_layers:
            ncs = []
            for i in range(n):
                layer_p = jax.tree.map(lambda a: a[i], stack)
                lc = jax.tree.map(lambda a: a[i], layer_cache)
                x, nc_i = body(x, (layer_p, flags[i], lc))
                ncs.append(nc_i)
            nc = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
            return x, nc
        return jax.lax.scan(body, x, (stack, flags, layer_cache))

    if paired:
        # (local, global) pairs: local layers use ring window caches.
        stack = params["dense_layers"]
        pairs = jax.tree.map(
            lambda a: a.reshape((a.shape[0] // 2, 2) + a.shape[1:]), stack)
        local_p = jax.tree.map(lambda a: a[:, 0], pairs)
        global_p = jax.tree.map(lambda a: a[:, 1], pairs)

        def pair_body(x, inp):
            lp, gp, lc, gc = inp
            x, nlc = _layer(lp, x, c, positions, jnp.asarray(True), False,
                            cache=lc, cache_len=cache_len, ring=True)
            x, ngc = _layer(gp, x, c, positions, jnp.asarray(False), False,
                            cache=gc, cache_len=cache_len)
            return x, (nlc, ngc)

        if c.unroll_layers:  # analysis mode (roofline reconstruction)
            nls, ngs = [], []
            for i in range(c.n_layers // 2):
                sel = lambda a: a[i]  # noqa: E731
                x, (nlc, ngc) = pair_body(
                    x, (jax.tree.map(sel, local_p),
                        jax.tree.map(sel, global_p),
                        jax.tree.map(sel, cache["dense_local"]),
                        jax.tree.map(sel, cache["dense_global"])))
                nls.append(nlc)
                ngs.append(ngc)
            nl = jax.tree.map(lambda *xs: jnp.stack(xs), *nls)
            ng = jax.tree.map(lambda *xs: jnp.stack(xs), *ngs)
        else:
            x, (nl, ng) = jax.lax.scan(
                pair_body, x,
                (local_p, global_p, cache["dense_local"],
                 cache["dense_global"]))
        new_cache = {"dense_local": nl, "dense_global": ng}
    else:
        if n_dense:
            x, nc = run_stack(x, params["dense_layers"], cache["dense"],
                              n_dense, 0, False, ring=ring_all)
            new_cache["dense"] = nc
        if n_moe:
            x, nc = run_stack(x, params["moe_layers"], cache["moe"],
                              n_moe, n_dense, True, ring=ring_all)
            new_cache["moe"] = nc

    x = rms_norm(x, params["final_ln"], c.norm_eps)
    logits = _softcap(_mm(x[:, -1], params["lm_head"]), c.final_softcap)
    return logits, new_cache
