"""GNN + BatchHL integration demo: GraphCast-style mesh GNN whose
grid→mesh encoder graph is batch-dynamic (stations drop in/out), with
BatchHL maintaining hop distances that feed the neighbor sampler bias.

    PYTHONPATH=src python examples/gnn_demo.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import common as cc
from repro.data.synthetic import coherent_gnn_batch
from repro.models import gnn as gnn_lib
from repro.train.optimizer import AdamWConfig
from repro.train import train_step as ts_lib
from repro.graphs import generators as gen
from repro.graphs.coo import from_edges, make_batch
from repro.graphs.sampler import build_csr, sample_neighbors
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.batch import batchhl_update

# --- 1. train a reduced GraphCast on a synthetic mesh ----------------------
cfg = cc.get_arch("graphcast").reduced_config()
batch = coherent_gnn_batch("graphcast", n_nodes=200, avg_deg=4,
                           d_feat=cfg.d_in, d_out=cfg.d_out)
params = gnn_lib.init_params(jax.random.PRNGKey(0), cfg)
opt = AdamWConfig(lr=1e-3)
step = jax.jit(ts_lib.make_generic_train_step(
    lambda p, b: gnn_lib.loss_fn(p, b, cfg), opt))
state = ts_lib.init_train_state(params, opt)
for i in range(30):
    state, aux = step(state, batch)
print(f"graphcast-reduced trained 30 steps, loss={float(aux['loss']):.4f}")

# --- 2. BatchHL maintains distances on the (dynamic) station graph ---------
n = 1000
edges = gen.barabasi_albert(n, 3, seed=2)
g = from_edges(n, edges, edges.shape[0] + 64)
landmarks = select_landmarks_by_degree(g, 8)
lab = build_labelling(g, landmarks)
ups = gen.random_batch_updates(edges, n, n_ins=20, n_del=20, seed=3)
g, lab, aff = batchhl_update(g, make_batch(ups, pad_to=40), lab)
print(f"station graph updated, {int(jnp.sum(aff))} affected pairs")

# --- 3. distance labels bias the neighbor sampler ---------------------------
# closeness = negative min distance to any landmark (fresh from BatchHL)
closeness = -jnp.min(lab.dist, axis=0).astype(jnp.float32)
csr = build_csr(n, edges)
seeds = jnp.arange(32, dtype=jnp.int32)
nbrs_biased, _ = sample_neighbors(csr, seeds, 8, jax.random.PRNGKey(1),
                                  bias=closeness)
nbrs_plain, _ = sample_neighbors(csr, seeds, 8, jax.random.PRNGKey(1))
d_b = float(jnp.mean(jnp.min(lab.dist, axis=0)[nbrs_biased]))
d_p = float(jnp.mean(jnp.min(lab.dist, axis=0)[nbrs_plain]))
print(f"sampler: mean landmark-distance of sampled neighbors "
      f"biased={d_b:.2f} vs uniform={d_p:.2f} (biased should be ≤)")
