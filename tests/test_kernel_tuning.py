"""Golden tests for every sweep configuration the autotuner may emit.

The autotuner (`core/autotune.py`) is a pure performance decision only if
every candidate in its space — each (impl, block_v, block_e, tile_shards)
point — computes the *identical* sweep. These tests pin that: each
candidate's `relax_sweep` / `relax_sweep_sorted` / `edge_relax` output is
bit-compared against the plain jnp segment-min reference, on a topology
with capacity slack, ragged edge counts (block_e not dividing per-block
counts), a ragged tail destination block, and the degenerate one-block
tiling. The rectangular min-plus kernel the tuned query path leans on is
pinned the same way.

Deliberately fast (no `slow` mark): tiny graphs keep interpret-mode
Pallas in the milliseconds so the fast `-m "not slow"` CI job runs the
full candidate space on every push.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autotune import TuneConfig, candidate_space
from repro.graphs.segment import masked_segment_min
from repro.kernels.edge_relax import ops as er_ops
from repro.kernels.minplus.ops import minplus_bound

INF32 = 1 << 29


def _topology(n=61, m=240, seed=0):
    """Random multigraph slots with capacity slack and per-sweep churn.

    `keep` marks occupied slots (what prepare-time sees); `mask` is the
    live-edge mask of one particular sweep (a strict subset — deletions
    since prepare). n=61 is deliberately not block_v-aligned so every
    tiling has a ragged tail block.
    """
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    keep = rng.random(m) < 0.8
    mask = keep & (rng.random(m) < 0.85)
    keys = rng.integers(0, 2 * n, n).astype(np.int32)
    hub = rng.random(n) < 0.3
    return src, dst, keep, mask, keys, hub


def _ref_sweep(keys, src, dst, mask, n, step, inf, clear_bit=0, hub=None):
    cand = jnp.minimum(jnp.asarray(keys)[np.asarray(src)] + step, inf)
    if hub is not None and clear_bit:
        cand = jnp.where(jnp.asarray(hub)[np.asarray(dst)],
                         cand & ~jnp.int32(clear_bit), cand)
    return masked_segment_min(cand, jnp.asarray(dst), n,
                              jnp.asarray(mask), inf)


def _run_config(cfg: TuneConfig, src, dst, keep, mask, keys, hub, n,
                step=2, clear_bit=1):
    keys_j = jnp.asarray(keys)
    mask_j = jnp.asarray(mask)
    hub_j = jnp.asarray(hub)
    if cfg.impl == "sorted":
        sg = er_ops.prepare_sorted(src, dst, keep, n)
        return er_ops.relax_sweep_sorted(keys_j, sg, mask_j, step, INF32,
                                         clear_bit=clear_bit, hub=hub_j)
    bg = er_ops.prepare_topology(src, dst, keep, n, block_v=cfg.block_v,
                                 shards=cfg.tile_shards, block_e=cfg.block_e)
    return er_ops.relax_sweep(keys_j, bg, mask_j, step, INF32,
                              clear_bit=clear_bit, hub=hub_j)


# --- every config the tuner may emit ---------------------------------------

_SPACE = candidate_space(shards=2, block_v=32, include_kernel=True)


@pytest.mark.parametrize(
    "cfg", _SPACE,
    ids=[f"{c.impl}-bv{c.block_v}-be{c.block_e}-ts{c.tile_shards}"
         for c in _SPACE])
def test_candidate_space_bit_parity(cfg):
    """Every point in the tuner's candidate space (kernel grid forced on,
    as on TPU) produces the jnp reference bit-for-bit — including the
    block_v > n degenerate single-block tilings the KERNEL_BLOCK_V grid
    collapses to at this size."""
    src, dst, keep, mask, keys, hub = _topology()
    got = _run_config(cfg, src, dst, keep, mask, keys, hub, n=61)
    want = _ref_sweep(keys, src, dst, mask, 61, 2, INF32,
                      clear_bit=1, hub=hub)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_candidate_space_shape_off_tpu():
    """Off-TPU the tuner only ever emits the sorted impl (interpret-mode
    kernel timings are not speed-representative), and every emitted
    config survives the table's JSON round-trip."""
    space = candidate_space(shards=2, block_v=64, include_kernel=False)
    assert space == [TuneConfig("sorted", 64, None, 2)]
    for cfg in candidate_space(shards=4, block_v=128, include_kernel=True):
        assert cfg.impl in ("kernel", "sorted")
        assert TuneConfig.from_dict(cfg.to_dict()) == cfg


# --- ragged block_e chunking ------------------------------------------------

@pytest.mark.parametrize("shards", [1, 2])
@pytest.mark.parametrize("block_e", [1, 7, 13, 1024])
def test_ragged_block_e_chunking(shards, block_e):
    """block_e values that do not divide the per-block edge counts (and
    the two extremes: one edge per row, one row per block) chunk blocks
    into ragged rows — the segment-min epilogue must reassemble them
    bit-identically."""
    src, dst, keep, mask, keys, hub = _topology(seed=shards * 31 + block_e)
    cfg = TuneConfig("kernel", 16, block_e, shards)
    got = _run_config(cfg, src, dst, keep, mask, keys, hub, n=61)
    want = _ref_sweep(keys, src, dst, mask, 61, 2, INF32,
                      clear_bit=1, hub=hub)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_chunked_rows_hidden_in_short_last_shard():
    """Chunked tiling whose extra rows fit inside a short last shard.

    n=24, block_v=8, shards=2: three destination blocks, so the last
    shard owns only one (nb_loc=2, one block short). block_e=4 chunks
    that shard's lone block into two rows — exactly filling the short
    shard, so NR_loc == nb_loc and post-shard shapes look unchunked.
    The tiling must still report chunked and fold the per-row partials;
    inferring chunkedness from shapes silently dropped relaxations here.
    """
    n = 24
    rng = np.random.default_rng(0)
    dst = np.array([1, 9, 16, 17, 18, 19, 20, 21, 2, 10], np.int32)
    src = rng.integers(0, n, len(dst)).astype(np.int32)
    keep = np.ones(len(dst), bool)
    keys = rng.integers(0, 2 * n, n).astype(np.int32)
    bg = er_ops.prepare_topology(src, dst, keep, n, block_v=8, shards=2,
                                 block_e=4)
    assert bg.chunked and bg.src_t.shape[1] == bg.nb
    got = er_ops.relax_sweep(jnp.asarray(keys), bg, jnp.asarray(keep),
                             1, INF32)
    want = _ref_sweep(keys, src, dst, keep, n, 1, INF32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_degenerate_single_block():
    """block_v >= n: the whole vertex set is one destination block."""
    src, dst, keep, mask, keys, hub = _topology(n=30, m=90, seed=7)
    for cfg in (TuneConfig("kernel", 64, None, 1),
                TuneConfig("kernel", 64, 5, 1)):
        got = _run_config(cfg, src, dst, keep, mask, keys, hub, n=30)
        want = _ref_sweep(keys, src, dst, mask, 30, 2, INF32,
                          clear_bit=1, hub=hub)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", ["kernel", "sorted"])
def test_no_hub_plain_relaxation(impl):
    """clear_bit=0 / hub=None variant (construction + BiBFS sweeps)."""
    src, dst, keep, mask, keys, _ = _topology(seed=11)
    if impl == "sorted":
        sg = er_ops.prepare_sorted(src, dst, keep, 61)
        got = er_ops.relax_sweep_sorted(jnp.asarray(keys), sg,
                                        jnp.asarray(mask), 1, INF32)
    else:
        bg = er_ops.prepare_topology(src, dst, keep, 61, block_v=16,
                                     shards=2, block_e=7)
        got = er_ops.relax_sweep(jnp.asarray(keys), bg,
                                 jnp.asarray(mask), 1, INF32)
    want = _ref_sweep(keys, src, dst, mask, 61, 1, INF32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", ["kernel", "sorted"])
def test_all_edges_masked_out(impl):
    """A sweep whose live-edge mask is empty returns all-INF (jax's
    segment_min int32-max fill must be clamped, never leaked)."""
    src, dst, keep, _, keys, hub = _topology(seed=13)
    mask = np.zeros_like(keep)
    cfg = (TuneConfig("sorted", 16, None, 1) if impl == "sorted"
           else TuneConfig("kernel", 16, 7, 2))
    got = _run_config(cfg, src, dst, keep, mask, keys, hub, n=61)
    np.testing.assert_array_equal(np.asarray(got), np.full(61, INF32))


# --- legacy baked-validity entry (edge_relax) -------------------------------

@pytest.mark.parametrize("block_e", [None, 7])
def test_edge_relax_chunked_parity(block_e):
    """The legacy `edge_relax` (validity baked at prepare time) stays
    bit-identical to its oracle on chunked and unchunked tilings."""
    src, dst, keep, _, keys, _ = _topology(seed=17)
    bg = er_ops.prepare(src, dst, keep, 61, block_v=16, shards=2,
                        block_e=block_e)
    got = er_ops.edge_relax(jnp.asarray(keys), bg, 1, use_pallas=True)
    want = _ref_sweep(keys, src, dst, keep, 61, 1, INF32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --- rectangular min-plus ---------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 1, 1), (7, 4, 12), (3, 16, 16),
                                   (5, 9, 2)])
def test_minplus_rectangular_parity(shape):
    """The min-plus kernel behind the tuned query path: rectangular
    S [B,P] × H [P,R] × T [B,R] shapes (including the shard-local P < R
    slice `core/shard.py` contracts) match the jnp oracle bitwise."""
    b, p, r = shape
    rng = np.random.default_rng(b * 100 + p * 10 + r)
    s = jnp.asarray(rng.integers(0, INF32, (b, p)).astype(np.int32))
    h = jnp.asarray(rng.integers(0, INF32, (p, r)).astype(np.int32))
    t = jnp.asarray(rng.integers(0, INF32, (b, r)).astype(np.int32))
    got = minplus_bound(s, h, t, use_pallas=True)
    want = minplus_bound(s, h, t, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
