"""Pure-jnp oracle for the edge-relaxation kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

INF32 = 1 << 29  # plain int: pallas kernels must not capture traced constants


def edge_relax(keys: jax.Array, src: jax.Array, dst: jax.Array,
               valid: jax.Array, step, n: int) -> jax.Array:
    """cand[v] = min over valid edges (u,v) of keys[u] + step; INF if none."""
    cand = jnp.minimum(keys[src] + step, INF32)
    cand = jnp.where(valid, cand, INF32)
    out = jax.ops.segment_min(cand, dst, num_segments=n)
    return jnp.minimum(out, INF32)
