"""Config plumbing: arch registry, per-cell input layouts, step builders,
and shardings. One place owns the (arch × shape × mesh) → (step_fn,
input ShapeDtypeStructs, in/out shardings) mapping used by the dry-run,
smoke tests and benchmarks alike.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.data import synthetic as synth
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts_lib

# --------------------------------------------------------------------------
# Cell description
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Cell:
    """One (arch × input-shape) dry-run cell."""
    arch_id: str
    shape_name: str
    kind: str                    # train | prefill | decode | serve | retrieval
    step_fn: Callable            # jit-able
    arg_specs: tuple             # ShapeDtypeStruct pytrees (positional)
    in_specs: tuple              # PartitionSpec pytrees (positional)
    out_specs: Any               # PartitionSpec pytree
    flops_note: dict             # {model_flops, tokens, ...} for §Roofline


LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# n_pad/e2_pad: node/edge arrays padded to multiples of 512 so every mesh
# (256 or 512 devices) shards them evenly; validity masks carry true sizes.
GNN_SHAPES = {
    "full_graph_sm": dict(kind="train", n_nodes=2708, n_edges=10556,
                          d_feat=1433, n_pad=3072, e2_pad=21504),
    "minibatch_lg": dict(kind="train", n_nodes=169984, n_edges=168960,
                         d_feat=602, sampled=True, n_pad=169984,
                         e2_pad=337920),
    "ogb_products": dict(kind="train", n_nodes=2449029, n_edges=61859140,
                         d_feat=100, n_pad=2449408, e2_pad=123719680),
    "molecule": dict(kind="train", n_nodes=3840, n_edges=8192, d_feat=16,
                     n_graphs=128, n_pad=4096, e2_pad=16384),
}

MIND_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512, n_cands=1000),
    "serve_bulk": dict(kind="serve", batch=262144, n_cands=1),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_cands=1_000_000),
}


def batch_axes(pod: bool):
    return ("pod", "data") if pod else ("data",)


# --------------------------------------------------------------------------
# LM cells
# --------------------------------------------------------------------------

def lm_cell(cfg, shape_name: str, pod: bool,
            opt_cfg: opt_lib.AdamWConfig | None = None,
            scheme: str | None = None) -> Cell:
    from repro.models import transformer as tfm
    sh = LM_SHAPES[shape_name]
    bax = batch_axes(pod)
    if scheme is None:
        # §Perf finding: v2 wins for train/prefill (×5-14 on the dominant
        # term) but regresses decode collectives (weight gathers for one
        # token); decode keeps v1, whose contraction-dim layout GSPMD
        # already turns into tiny activation psums.
        scheme = "v1" if sh["kind"] == "decode" else "v2"
    pspecs = tfm.param_specs(cfg, pod, scheme=scheme)
    pshapes = tfm.param_shapes(cfg)
    opt_cfg = opt_cfg or opt_lib.AdamWConfig()

    if sh["kind"] == "train":
        if sh["batch"] % 256 == 0:
            cfg = dataclasses.replace(cfg, attn_2d_batch=True)
        layout = synth.lm_train_layout(sh["batch"], sh["seq"], cfg.vocab)
        batch_specs = {k: P(bax, None) for k in layout}
        state_shapes = ts_lib.train_state_shapes(pshapes, opt_cfg)
        state_specs = {
            "params": pspecs,
            "opt": {"m": pspecs, "v": pspecs, "step": P()},
        }
        step = ts_lib.make_lm_train_step(cfg, opt_cfg)
        return Cell(cfg.name, shape_name, "train", step,
                    (state_shapes, synth.as_specs(layout)),
                    (state_specs, batch_specs),
                    (state_specs, {"loss": P()}),
                    dict(tokens=sh["batch"] * sh["seq"], train=True))

    # serving cells share the decode_step entry (prefill = multi-token)
    from repro.models.transformer import cache_shapes, decode_step
    if sh["kind"] == "prefill":
        q_tokens, cache_len0 = sh["seq"], 0
        max_len = sh["seq"]
        b = sh["batch"]
        seq_axis = "model"
    elif shape_name == "decode_32k":
        q_tokens, cache_len0 = 1, sh["seq"]
        max_len = sh["seq"] + 512
        b = sh["batch"]
        seq_axis = "model"
    else:  # long_500k: batch=1 → shard the cache sequence across everything
        q_tokens, cache_len0 = 1, sh["seq"]
        max_len = sh["seq"] + 512
        b = sh["batch"]
        seq_axis = (("pod", "data", "model") if pod
                    else ("data", "model"))
    cshapes = cache_shapes(cfg, b, max_len)
    cache_sp = _lm_cache_specs(cfg, pod, seq_axis, cshapes)
    layout = synth.lm_prefill_layout(b, q_tokens, cfg.vocab)
    tok_spec = {"tokens": P(bax if b > 1 else None, None)}

    def serve_step(params, cache, batch):
        logits, new_cache = decode_step(params, cache, batch["tokens"],
                                        jnp.int32(cache_len0), cfg)
        return logits, new_cache

    logits_spec = P(bax if b > 1 else None, "model")
    return Cell(cfg.name, shape_name, sh["kind"], serve_step,
                (pshapes, cshapes, synth.as_specs(layout)),
                (pspecs, cache_sp, tok_spec),
                (logits_spec, cache_sp),
                dict(tokens=b * q_tokens, kv_len=max_len, train=False))


def _lm_cache_specs(cfg, pod: bool, seq_axis, cshapes: dict) -> dict:
    """Specs mirroring the cache_shapes pytree: [L, B, S, ...] leaves get
    batch over data axes (when batch-sharded cells) and S over seq_axis."""
    bax = batch_axes(pod)
    b_ax = bax if seq_axis == "model" else None

    def leaf_spec(leaf):
        rank = len(leaf.shape)
        if rank == 4:    # MLA c_kv [L, B, S, r]
            return P(None, b_ax, seq_axis, None)
        return P(None, b_ax, seq_axis, None, None)
    return jax.tree.map(
        leaf_spec, cshapes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# --------------------------------------------------------------------------
# GNN cells
# --------------------------------------------------------------------------

def gnn_cell(cfg, shape_name: str, pod: bool,
             opt_cfg: opt_lib.AdamWConfig | None = None) -> Cell:
    from repro.models import gnn as gnn_lib
    sh = GNN_SHAPES[shape_name]
    bax = batch_axes(pod)
    opt_cfg = opt_cfg or opt_lib.AdamWConfig()

    d_feat = sh["d_feat"]
    n_graphs = sh.get("n_graphs")
    cfg = dataclasses.replace(cfg, d_in=d_feat)
    n_pad, e2 = sh["n_pad"], sh["e2_pad"]
    tri_cap = min(4 * e2, 1 << 27)
    layout = synth.gnn_layout(cfg.arch, n_pad, e2, d_feat,
                              cfg.d_out, n_graphs=n_graphs, tri_cap=tri_cap)

    # nodes/edges sharded over data(+pod); params replicated (small).
    def spec_for(k, v):
        shape = v[0]
        if k in ("targets",) and n_graphs is not None:
            return P(bax, None)
        row = bax if shape[0] % 512 == 0 else None
        return P(row, *([None] * (len(shape) - 1)))

    batch_specs = {k: spec_for(k, v) for k, v in layout.items()}
    pshapes = jax.eval_shape(
        lambda: gnn_lib.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = jax.tree.map(lambda _: P(), pshapes)
    state_shapes = ts_lib.train_state_shapes(pshapes, opt_cfg)
    state_specs = {"params": pspecs,
                   "opt": {"m": pspecs, "v": pspecs, "step": P()}}

    def loss(p, b):
        return gnn_lib.loss_fn(p, b, cfg)
    step = ts_lib.make_generic_train_step(loss, opt_cfg)
    return Cell(cfg.name, shape_name, "train", step,
                (state_shapes, synth.as_specs(layout)),
                (state_specs, batch_specs),
                (state_specs, {"loss": P()}),
                dict(nodes=sh["n_nodes"], edges=e2, train=True))


# --------------------------------------------------------------------------
# MIND cells
# --------------------------------------------------------------------------

def mind_cell(cfg, shape_name: str, pod: bool,
              opt_cfg: opt_lib.AdamWConfig | None = None) -> Cell:
    from repro.models import mind as mind_lib
    sh = MIND_SHAPES[shape_name]
    bax = batch_axes(pod)
    opt_cfg = opt_cfg or opt_lib.AdamWConfig()
    pshapes = mind_lib.param_shapes(cfg)
    pspecs = mind_lib.param_specs(cfg, pod)

    if sh["kind"] == "train":
        layout = synth.mind_train_layout(sh["batch"], cfg.hist_len,
                                         cfg.n_items)
        batch_specs = {k: P(bax, *([None] * (len(v[0]) - 1)))
                       for k, v in layout.items()}
        state_shapes = ts_lib.train_state_shapes(pshapes, opt_cfg)
        state_specs = {"params": pspecs,
                       "opt": {"m": pspecs, "v": pspecs, "step": P()}}

        def loss(p, b):
            return mind_lib.train_loss(p, b, cfg)
        step = ts_lib.make_generic_train_step(loss, opt_cfg)
        return Cell(cfg.name, shape_name, "train", step,
                    (state_shapes, synth.as_specs(layout)),
                    (state_specs, batch_specs),
                    (state_specs, {"loss": P()}),
                    dict(batch=sh["batch"], train=True))

    if sh["kind"] == "serve":
        layout = synth.mind_serve_layout(sh["batch"], cfg.hist_len,
                                         cfg.n_items, sh["n_cands"])
        batch_specs = {k: P(bax, *([None] * (len(v[0]) - 1)))
                       for k, v in layout.items()}

        def step(params, batch):
            return mind_lib.serve_scores(params, batch, cfg)
        return Cell(cfg.name, shape_name, "serve", step,
                    (pshapes, synth.as_specs(layout)),
                    (pspecs, batch_specs), P(bax, None),
                    dict(batch=sh["batch"], train=False))

    # retrieval: candidates sharded over the batch axes (10⁶ is not
    # divisible by 256, so the model axis stays off this dim)
    layout = synth.mind_retrieval_layout(cfg.hist_len, cfg.n_items,
                                         sh["n_cands"])
    cand_ax = bax
    batch_specs = {"hist": P(None, None), "hist_mask": P(None, None),
                   "cands": P(cand_ax)}

    def step(params, batch):
        return mind_lib.retrieval_scores(params, batch, cfg)
    return Cell(cfg.name, shape_name, "retrieval", step,
                (pshapes, synth.as_specs(layout)),
                (pspecs, batch_specs), P(None, cand_ax),
                dict(batch=sh["n_cands"], train=False))


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

def get_arch(arch_id: str):
    """Import the arch's config module by id."""
    import importlib
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_"))
    return mod


ALL_ARCHS = (
    "gemma2-9b", "minitron-4b", "granite-8b", "deepseek-v2-lite-16b",
    "mixtral-8x22b",
    "schnet", "dimenet", "mace", "graphcast",
    "mind",
)


def build_cell(arch_id: str, shape_name: str, pod: bool) -> Cell:
    mod = get_arch(arch_id)
    cfg = mod.model_config()
    if mod.FAMILY == "lm":
        return lm_cell(cfg, shape_name, pod)
    if mod.FAMILY == "gnn":
        return gnn_cell(cfg, shape_name, pod)
    if mod.FAMILY == "recsys":
        return mind_cell(cfg, shape_name, pod)
    if mod.FAMILY == "batchhl":
        return mod.build_cell(shape_name, pod)
    raise ValueError(mod.FAMILY)


def arch_shapes(arch_id: str) -> tuple[str, ...]:
    return get_arch(arch_id).SHAPES
