"""Mesh-sharded BatchHL (core/shard.py): sharded-vs-unsharded bit-parity.

In-process tests run on whatever host mesh the environment provides: the
degenerate 1-device mesh under plain pytest (conftest sets no XLA_FLAGS),
a real 8-device mesh under the CI `mesh` job
(XLA_FLAGS=--xla_force_host_platform_device_count=8) — instances use R=8
landmarks so the plane counts divide any device count up to 8. The
subprocess tests force the 8-device platform themselves regardless
(`launch/dryrun.py` idiom): the shard selftest sweeps every (data, model)
factorization of 8 on both sweep backends — with a non-divisible query
batch, exercising the pad/slice path — and the serving loop runs
end-to-end on a (4, 2) mesh against the BFS oracle.
"""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.graphs import generators as gen
from repro.graphs.coo import from_edges, make_batch
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.batch import batchhl_update
from repro.core.engine import RelaxEngine
from repro.core.query import batched_query
from repro.core.shard import (_check_planes, affected_vertices,
                              shard_batched_query, shard_batchhl_update,
                              shard_build_labelling)
from repro.launch.mesh import make_host_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True, scope="module")
def _fresh_compile_caches():
    """This module's first shard_map compile is the biggest single
    compile in the suite, and it runs ~200 tests deep; on top of the
    accumulated executables the XLA CPU client has segfaulted inside
    backend_compile. Start from a fresh client (test_weighted.py
    hygiene) — the re-compiles the earlier modules' shapes pay for
    later are all small."""
    jax.clear_caches()
    yield


def _env_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return env


def _instance(n=60, extra=70, r=8, seed=5):
    edges = gen.random_connected(n, extra_edges=extra, seed=seed)
    g = from_edges(n, edges, edges.shape[0] + 32)
    landmarks = select_landmarks_by_degree(g, r)
    return edges, g, landmarks


# --- host mesh (1-device under plain pytest, 8-device under the CI mesh
# --- job): the sharded code path must be bit-exact either way ---------------

def test_build_update_query_parity_host_mesh():
    mesh = make_host_mesh()
    edges, g, landmarks = _instance()
    n = g.n

    lab = build_labelling(g, landmarks)
    slab = shard_build_labelling(mesh, g, landmarks)
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(np.asarray(getattr(slab, f)),
                                      np.asarray(getattr(lab, f)))

    ups = gen.random_batch_updates(edges, n, n_ins=4, n_del=4, seed=2)
    batch = make_batch(ups, pad_to=8)
    g1, lab1, aff1 = batchhl_update(g, batch, lab, improved=True)
    sg1, slab1, saff1 = shard_batchhl_update(mesh, g, batch, slab)
    np.testing.assert_array_equal(np.asarray(saff1), np.asarray(aff1))
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(np.asarray(getattr(slab1, f)),
                                      np.asarray(getattr(lab1, f)))
    np.testing.assert_array_equal(np.asarray(sg1.valid), np.asarray(g1.valid))

    rng = np.random.default_rng(0)
    qs = jnp.asarray(rng.integers(0, n, 23), jnp.int32)
    qt = jnp.asarray(rng.integers(0, n, 23), jnp.int32)
    want = batched_query(g1, lab1, qs, qt)
    got = shard_batched_query(mesh, sg1, slab1, qs, qt)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_basic_search_variant_parity_host_mesh():
    mesh = make_host_mesh()
    edges, g, landmarks = _instance(seed=8)
    lab = build_labelling(g, landmarks)
    ups = gen.random_batch_updates(edges, g.n, n_ins=3, n_del=3, seed=4)
    batch = make_batch(ups, pad_to=6)
    _, lab1, aff1 = batchhl_update(g, batch, lab, improved=False)
    _, slab1, saff1 = shard_batchhl_update(mesh, g, batch, lab,
                                           improved=False)
    np.testing.assert_array_equal(np.asarray(saff1), np.asarray(aff1))
    np.testing.assert_array_equal(np.asarray(slab1.dist),
                                  np.asarray(lab1.dist))


def test_affected_vertices_or_merge():
    mesh = make_host_mesh()
    edges, g, landmarks = _instance()
    lab = build_labelling(g, landmarks)
    ups = gen.random_batch_updates(edges, g.n, n_ins=4, n_del=4, seed=3)
    batch = make_batch(ups, pad_to=8)
    _, _, aff = shard_batchhl_update(mesh, g, batch, lab)
    got = affected_vertices(mesh, aff)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.any(aff, axis=0)))


def test_plane_divisibility_validation():
    with pytest.raises(ValueError, match="divisible"):
        _check_planes(3, 2, "model")
    _check_planes(4, 2, "model")  # divides: no raise
    with pytest.raises(ValueError, match="divide"):
        make_host_mesh(model=3)   # 1 CPU device can't split a model axis


def test_sharded_update_accepts_engine_plan():
    """A real Pallas plan (tiles and all) through the sharded path must
    give bit-identical results to the per-shard jnp reference — the
    shard-aware tiling composes with the mesh, no downgrade anywhere."""
    from repro.graphs.coo import apply_batch
    mesh = make_host_mesh()
    edges, g, landmarks = _instance(seed=12)
    lab = build_labelling(g, landmarks)
    ups = gen.random_batch_updates(edges, g.n, n_ins=3, n_del=3, seed=6)
    batch = make_batch(ups, pad_to=6)
    g_next = apply_batch(g, batch)
    plan = RelaxEngine(backend="pallas", block_v=16,
                       shards=2).prepare(g_next)
    _, lab_a, aff_a = shard_batchhl_update(mesh, g, batch, lab)
    _, lab_b, aff_b = shard_batchhl_update(mesh, g, batch, lab, plan=plan,
                                           g_new=g_next)
    np.testing.assert_array_equal(np.asarray(aff_b), np.asarray(aff_a))
    np.testing.assert_array_equal(np.asarray(lab_b.dist),
                                  np.asarray(lab_a.dist))


# --- forced multi-device coverage (subprocess; see module docstring) ------

@pytest.mark.slow
def test_multidevice_parity_selftest():
    """Bit-parity on every (data, model) factorization of an 8-device CPU
    mesh, including the padded-query path (B=37)."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.core.shard"],
        env=_env_8dev(), cwd=REPO, capture_output=True, text=True,
        timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "selftest OK on 8 device(s)" in out.stdout, out.stdout


@pytest.mark.slow
def test_serve_mesh_host_multidevice():
    """The full serving tick loop on a (data=4, model=2) mesh, verified
    against the BFS oracle each tick."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--n", "300", "--batches", "2", "--batch-size", "30",
         "--queries", "48", "--landmarks", "8",
         "--mesh", "host", "--shards", "2", "--verify"],
        env=_env_8dev(), cwd=REPO, capture_output=True, text=True,
        timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "serve loop done" in out.stdout, out.stdout
    assert out.stdout.count("verify: 0/48 mismatches") == 2, out.stdout
