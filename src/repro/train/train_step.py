"""Train-step factories: loss → grad → clip → AdamW, with optional
microbatch gradient accumulation (compute/comm overlap knob at scale).

Every factory returns a pure function suitable for jax.jit with explicit
in/out shardings (the launcher owns mesh placement).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train import optimizer as opt_lib


def make_lm_train_step(cfg, opt_cfg: opt_lib.AdamWConfig,
                       microbatch: int | None = None) -> Callable:
    """Language-model train step over {tokens, targets} [B, S] int32."""
    from repro.models import transformer as tfm

    def loss_fn(params, tokens, targets):
        return tfm.chunked_loss(params, tokens, targets, cfg)

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        tokens, targets = batch["tokens"], batch["targets"]
        if microbatch:
            b = tokens.shape[0]
            nm = b // microbatch
            tk = tokens.reshape(nm, microbatch, -1)
            tg = targets.reshape(nm, microbatch, -1)

            def acc_step(carry, inp):
                loss_acc, grad_acc = carry
                t, g = inp
                loss, grads = jax.value_and_grad(loss_fn)(params, t, g)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, grad_acc, grads)), None

            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), zero_grads), (tk, tg))
            loss = loss / nm
            grads = jax.tree.map(lambda g: g / nm, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, tokens,
                                                      targets)
        new_params, new_opt = opt_lib.adamw_update(
            params, grads, state["opt"], opt_cfg)
        return {"params": new_params, "opt": new_opt}, {"loss": loss}

    return train_step


def make_generic_train_step(loss_fn: Callable,
                            opt_cfg: opt_lib.AdamWConfig) -> Callable:
    """Train step for any (params, batch) → scalar loss function
    (GNNs, recsys, and the BatchHL-adjacent models use this)."""

    def train_step(state: dict, batch: Any) -> tuple[dict, dict]:
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        new_params, new_opt = opt_lib.adamw_update(
            state["params"], grads, state["opt"], opt_cfg)
        return {"params": new_params, "opt": new_opt}, {"loss": loss}

    return train_step


def init_train_state(params: Any, opt_cfg: opt_lib.AdamWConfig) -> dict:
    return {"params": params, "opt": opt_lib.init_opt_state(params, opt_cfg)}


def train_state_shapes(params_shapes: Any,
                       opt_cfg: opt_lib.AdamWConfig) -> dict:
    return {"params": params_shapes,
            "opt": opt_lib.opt_state_shapes(params_shapes, opt_cfg)}
