"""Paper Figures 7+8: update time and query time under 8–48 landmarks."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.graphs.coo import make_batch
from repro.core.batch import batchhl_update
from repro.core.query import batched_query
from benchmarks import common as cm

LANDMARK_COUNTS = (8, 16, 32, 48)
BATCH = 128
N_QUERIES = 256


def run() -> list[str]:
    rows = []
    rng = np.random.default_rng(9)
    for r in LANDMARK_COUNTS:
        inst = cm.build_instance("ba_10k", n_landmarks=r)
        ups = cm.update_stream(inst.edges, inst.n, BATCH, "mixed", seed=23)
        b = make_batch(ups, pad_to=BATCH)
        t_u = cm.timeit(lambda: batchhl_update(inst.g, b, inst.lab))
        rows.append(cm.emit(f"fig7/ba_10k/update/R{r}", t_u,
                            f"batch={BATCH},label_size="
                            f"{int(inst.lab.label_size())}"))
        qs = jnp.asarray(rng.integers(0, inst.n, N_QUERIES), jnp.int32)
        qt = jnp.asarray(rng.integers(0, inst.n, N_QUERIES), jnp.int32)
        t_q = cm.timeit(lambda: batched_query(inst.g, inst.lab, qs, qt))
        rows.append(cm.emit(f"fig8/ba_10k/query/R{r}", t_q / N_QUERIES,
                            f"batch={N_QUERIES}"))
    return rows


if __name__ == "__main__":
    run()
