"""Directed BatchHL under the engine's Pallas backend (DESIGN.md §3).

`tests/test_directed.py` pins the directed stack against the directed
BFS oracle, but only on the jnp reference path. This module pins the
*backend dispatch*: construction, batch update, and directed queries
driven through per-orientation `RelaxPlan`s (the forward arc table and
its reversal are distinct topologies to the tiler) must be bit-identical
to the jnp run, with an oracle spot-check on the answers. Deterministic
and hypothesis-free, so it runs in the fast job and on bare checkouts.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.graphs.coo import make_batch, INF_D
from repro.core import ref
from repro.core.directed import (apply_batch_directed,
                                 batchhl_update_directed,
                                 build_directed_labelling, directed_query,
                                 from_arcs)
from repro.core.engine import RelaxEngine


def _digraph(seed=0, n=40, extra=50):
    rng = np.random.default_rng(seed)
    arcs = set()
    for v in range(1, n):  # weakly-connected backbone
        u = int(rng.integers(v))
        arcs.add((u, v) if rng.random() < 0.7 else (v, u))
    while len(arcs) < n - 1 + extra:
        u, v = int(rng.integers(n)), int(rng.integers(n))
        if u != v:
            arcs.add((u, v))
    return np.asarray(sorted(arcs), np.int32), n, rng


def _adj_out(g):
    adj = {v: set() for v in range(g.n)}
    for s, d, ok in zip(np.asarray(g.src), np.asarray(g.dst),
                        np.asarray(g.valid)):
        if ok:
            adj[int(s)].add(int(d))
    return adj


def _plans(g, block_v=16):
    """One engine per orientation: fwd and rev are distinct topologies,
    each with its own tiling/fingerprint."""
    ef = RelaxEngine(backend="pallas", block_v=block_v)
    eb = RelaxEngine(backend="pallas", block_v=block_v)
    return ef.prepare(g.fwd()), eb.prepare(g.rev())


def _assert_directed_equal(a, b):
    for plane in ("fwd", "bwd"):
        for f in ("dist", "hub", "highway"):
            np.testing.assert_array_equal(
                np.asarray(getattr(getattr(a, plane), f)),
                np.asarray(getattr(getattr(b, plane), f)),
                err_msg=f"{plane}.{f}")


def test_directed_construction_backend_parity():
    arcs, n, _ = _digraph()
    g = from_arcs(n, arcs, arcs.shape[0] + 8)
    lms = jnp.asarray([0, 5, 9], jnp.int32)
    pf, pb = _plans(g)
    _assert_directed_equal(build_directed_labelling(g, lms),
                           build_directed_labelling(g, lms, pf, pb))


def test_directed_update_and_query_backend_parity():
    arcs, n, rng = _digraph(seed=1)
    g = from_arcs(n, arcs, arcs.shape[0] + 8)
    lms = jnp.asarray([0, 3, 7], jnp.int32)
    lab = build_directed_labelling(g, lms)

    ups = [(int(arcs[3, 0]), int(arcs[3, 1]), True),
           (int(arcs[11, 0]), int(arcs[11, 1]), True),
           (7, 31, False), (22, 2, False), (15, 33, False)]
    batch = make_batch(ups, pad_to=len(ups) + 1)
    # Plans from the post-update snapshot, one per orientation.
    g2 = apply_batch_directed(g, batch)
    pf2, pb2 = _plans(g2)

    gj, lab_j, aff_j = batchhl_update_directed(g, batch, lab)
    gp, lab_p, aff_p = batchhl_update_directed(g, batch, lab, pf2, pb2)
    np.testing.assert_array_equal(np.asarray(aff_j), np.asarray(aff_p))
    _assert_directed_equal(lab_j, lab_p)

    qs = jnp.asarray(rng.integers(0, n, 24), jnp.int32)
    qt = jnp.asarray(rng.integers(0, n, 24), jnp.int32)
    d_j = directed_query(gj, lab_j, qs, qt)
    d_p = directed_query(gp, lab_p, qs, qt, plan_fwd=pf2, plan_bwd=pb2)
    np.testing.assert_array_equal(np.asarray(d_j), np.asarray(d_p))

    adj = _adj_out(gj)
    for k in range(24):
        want = ref.bfs_dist_directed(adj, n, int(qs[k]))[int(qt[k])]
        want = 0 if int(qs[k]) == int(qt[k]) else want
        want = int(INF_D) if want == ref.INF else int(want)
        assert int(d_j[k]) == want, (int(qs[k]), int(qt[k]))
