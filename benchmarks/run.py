"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Modules:
  table3_update_time   — Table 3 (BHL⁺/BHL/BHLˢ/UHL⁺ update time)
  table4_construction  — Table 4 (construction, query time, label size)
  table5_affected      — Table 5 + Fig. 2 (affected-vertex counts)
  table6_directed      — Table 6 (directed graphs, two-plane BatchHL)
  fig6_batch_sizes     — Fig. 6 (amortized total time vs batch size)
  fig7_landmarks       — Figs. 7/8 (update/query time vs landmarks)

``--fast`` trims datasets for CI-ish runs; default runs everything.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names")
    args = ap.parse_args()

    from benchmarks import (table3_update_time, table4_construction,
                            table5_affected, table6_directed,
                            fig6_batch_sizes, fig7_landmarks)
    modules = {
        "table3": table3_update_time,
        "table4": table4_construction,
        "table5": table5_affected,
        "table6": table6_directed,
        "fig6": fig6_batch_sizes,
        "fig7": fig7_landmarks,
    }
    picked = (args.only.split(",") if args.only else list(modules))
    print("name,us_per_call,derived")
    t0 = time.time()
    rows = 0
    for name in picked:
        mod = modules[name]
        try:
            if args.fast and name in ("table3", "table4"):
                out = mod.run(datasets=("ba_2k",))
            else:
                out = mod.run()
            rows += len(out)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", file=sys.stderr)
            raise
    print(f"# {rows} rows in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
