"""Distance queries: Eq.-3 highway upper bound + bounded BiBFS on G[V\\R].

Queries are processed in batches (the serving reality at scale). The upper
bound over a batch is a min-plus (tropical) product
    d⊤[q] = min_{i,j}  L[i, s_q] + H[i, j] + L[j, t_q]
dispatched by `use_kernel`: the Pallas `minplus` kernel when True, a pure
jnp contraction when False (the default everywhere off-TPU). The bounded
bidirectional BFS runs all queries in lockstep as masked frontier waves
with a global early-exit; each wave is an edge-relaxation sweep routed
through the relaxation engine (`core/engine.py`), so passing a `RelaxPlan`
runs the tiled Pallas `edge_relax` kernel while the default `plan=None`
runs the jnp segment-min reference — see DESIGN.md §3.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graphs.coo import Graph, INF_D
from repro.core.engine import RelaxPlan, relax_sweep
from repro.core.labelling import HighwayLabelling, landmark_onehot


def effective_label_planes(dist: jax.Array, hub: jax.Array, own: jax.Array,
                           landmarks_full: jax.Array) -> jax.Array:
    """[P, V] effective label values for a plane slice (dist/hub [P, V]).

    `own` [P] is each plane's landmark id, `landmarks_full` [R] the complete
    landmark set. Entirely per-plane, so `core/shard.py` evaluates it on
    shard-local planes; `effective_labels` below is the full-plane wrapper.
    """
    v_ids = jnp.arange(dist.shape[1])
    is_landmark_v = jnp.any(v_ids[None, :] == landmarks_full[:, None], axis=0)
    mask = (dist < INF_D) & ~hub & ~is_landmark_v[None, :]
    vals = jnp.where(mask, dist, INF_D)
    # Landmark columns get the trivial (own, 0) one-hot entry.
    onehot = jnp.where(own[:, None] == landmarks_full[None, :],
                       0, INF_D).astype(jnp.int32)
    cols = landmarks_full
    return vals.at[:, cols].set(jnp.minimum(vals[:, cols], onehot))


def effective_labels(labelling: HighwayLabelling) -> jax.Array:
    """[R, V] label values with landmark columns replaced by highway one-hots.

    For a landmark vertex v = r_k the minimal labelling stores nothing; its
    Eq.-3 role is played by the trivial entry (r_k, 0), which composes with
    the highway to give exact landmark distances (Def. 3.3).
    """
    return effective_label_planes(labelling.dist, labelling.hub,
                                  labelling.landmarks, labelling.landmarks)


def _minplus_bound(s_lab: jax.Array, highway: jax.Array,
                   t_lab: jax.Array) -> jax.Array:
    """[B,R] ⊗ [R,R] ⊗ [B,R] tropical contraction → [B]."""
    # mid[b, j] = min_i s_lab[b, i] + H[i, j]
    mid = jnp.min(s_lab[:, :, None] + highway[None, :, :], axis=1)
    return jnp.min(mid + t_lab, axis=1)


def query_upper_bound(labelling: HighwayLabelling, s: jax.Array,
                      t: jax.Array, use_kernel: bool = False) -> jax.Array:
    """d⊤ for query pairs (s[q], t[q]) — Eq. 3.

    use_kernel=False (the default) runs the jnp tropical contraction;
    use_kernel=True dispatches to the Pallas `minplus` kernel (compiled on
    TPU, interpret-mode elsewhere).
    """
    lab = effective_labels(labelling)
    s_lab = lab[:, s].T  # [B, R]
    t_lab = lab[:, t].T
    s_lab = jnp.minimum(s_lab, INF_D)
    t_lab = jnp.minimum(t_lab, INF_D)
    if use_kernel:
        from repro.kernels.minplus import ops as minplus_ops
        return minplus_ops.minplus_bound(s_lab, labelling.highway, t_lab)
    return jnp.minimum(_minplus_bound(s_lab, labelling.highway, t_lab), INF_D)


@partial(jax.jit, static_argnames=("max_steps",))
def bounded_bibfs(g: Graph, landmarks: jax.Array, s: jax.Array, t: jax.Array,
                  bound: jax.Array, max_steps: int = 64,
                  plan: RelaxPlan | None = None) -> jax.Array:
    """Distance-bounded bidirectional search on G[V\\R], batched over
    queries.

    Returns d_{G[V\\R]}(s,t) clamped at `bound` (if the sparsified distance
    is >= bound the return is >= bound, which is all the caller needs).
    Expansion is a Bellman-Ford wave — an engine-dispatched relaxation
    sweep over each side's whole distance plane, vmapped over the query
    batch (`plan` selects the backend, None = jnp). After k waves a side
    is exact on every shortest path of ≤ k edges, so once both sides have
    run ls/lt waves any path still unaccounted for has ≥ ls+lt+1 edges
    and therefore weight ≥ (ls+lt+1)·wmin — the weighted termination
    bound. With w ≡ 1 (wmin = 1) the waves and the bound degenerate to
    the level-synchronous BiBFS this replaces, bit-identically.
    """
    n = g.n
    b = s.shape[0]
    blocked = landmark_onehot(landmarks, n)                   # bool[V]

    inf = INF_D
    dist_s = jnp.full((b, n), inf, jnp.int32).at[jnp.arange(b), s].set(0)
    dist_t = jnp.full((b, n), inf, jnp.int32).at[jnp.arange(b), t].set(0)
    # A landmark endpoint never expands (searches run on G[V\R]).
    s_ok = ~blocked[s]
    t_ok = ~blocked[t]
    dist_s = jnp.where(s_ok[:, None], dist_s, inf)
    dist_t = jnp.where(t_ok[:, None], dist_t, inf)

    # Smallest live edge weight, for the termination bound. Clipped: ≥ 1
    # so the bound still advances on w ≡ 1 graphs, and ≤ 2^20 so the
    # product (ls+lt+1)·wmin — at most (max_steps+1)·wmin — stays far from
    # int32 wrap even on near-INF_D weights (an edgeless graph min()s to
    # INF_D before the clip).
    wmin = jnp.clip(jnp.min(jnp.where(g.valid, g.w, INF_D), initial=INF_D),
                    1, 1 << 20)

    def expand(dist_x):
        """One Bellman-Ford wave: relax every live edge from the current
        plane — the same sweep primitive (and the same kernel) as the
        update-side searches. Landmark vertices never acquire a distance
        (the search runs on G[V\\R])."""
        cand = jax.vmap(
            lambda k: relax_sweep(plan, g, k, 1, inf))(dist_x)
        cand = jnp.where(blocked[None, :], inf, cand)
        return jnp.minimum(dist_x, cand)

    def best_meet(ds, dt):
        return jnp.min(jnp.minimum(ds + dt, inf), axis=1)     # [B]

    def cond(state):
        ds, dt, ls, lt, fs, ft, best, step = state
        can_improve = (ls + lt + 1) * wmin < jnp.minimum(best, bound)
        return jnp.any(can_improve) & (step < max_steps)

    def body(state):
        ds, dt, ls, lt, fs, ft, best, step = state
        # Expand the side whose last wave changed fewer entries (the
        # paper's smaller-frontier BiBFS optimization; on w ≡ 1 graphs
        # the changed count IS the new frontier size). lax.cond executes
        # only the chosen side's sweep — the edge-array read per wave is
        # the memory floor here.
        expand_s = fs <= ft

        def s_side(args):
            ds, dt, ls, lt, fs, ft = args
            nd = expand(ds)
            return nd, dt, ls + 1, lt, jnp.sum(nd != ds), ft

        def t_side(args):
            ds, dt, ls, lt, fs, ft = args
            nd = expand(dt)
            return ds, nd, ls, lt + 1, fs, jnp.sum(nd != dt)

        ds, dt, ls, lt, fs, ft = jax.lax.cond(expand_s, s_side, t_side,
                                              (ds, dt, ls, lt, fs, ft))
        best = jnp.minimum(best, best_meet(ds, dt))
        return ds, dt, ls, lt, fs, ft, best, step + 1

    best0 = best_meet(dist_s, dist_t)
    state = (dist_s, dist_t, jnp.zeros((), jnp.int32),
             jnp.zeros((), jnp.int32),
             jnp.sum(dist_s == 0), jnp.sum(dist_t == 0),
             best0, jnp.zeros((), jnp.int32))
    *_, best, _ = jax.lax.while_loop(cond, body, state)
    return best


def batched_query(g: Graph, labelling: HighwayLabelling, s: jax.Array,
                  t: jax.Array, max_steps: int = 64,
                  use_kernel: bool = False,
                  plan: RelaxPlan | None = None) -> jax.Array:
    """Exact distances Q(s,t) = min(d_{G[V\\R]}(s,t), d⊤) — paper §4.

    `use_kernel` dispatches the upper bound to the minplus kernel; `plan`
    dispatches the BiBFS sweeps to the edge_relax kernel (both default to
    the jnp reference paths).
    """
    d_top = query_upper_bound(labelling, s, t, use_kernel=use_kernel)
    d_sparse = bounded_bibfs(g, labelling.landmarks, s, t, d_top, max_steps,
                             plan)
    out = jnp.minimum(d_sparse, d_top)
    return jnp.where(out >= INF_D, INF_D, out)
