"""mace [gnn]: n_layers=2 d_hidden=128 l_max=2 correlation_order=3 n_rbf=8
E(3)-equivariant higher-order message passing [arXiv:2206.07697; paper].

Equivariance note: features carry l ∈ {0,1,2} irreps (scalars, vectors,
traceless-symmetric rank-2); correlation order 3 is realized through the
v·T·v / |v|² / |T|² invariant contractions — see DESIGN.md for the
Clebsch–Gordan simplification relative to full e3nn MACE.
"""
from repro.models.gnn import GNNConfig

ARCH_ID = "mace"
FAMILY = "gnn"
SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")


def model_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID, arch="mace", d_in=16, d_hidden=128,
                     d_out=1, n_layers=2, l_max=2, correlation=3,
                     mace_n_rbf=8, cutoff=10.0)


def reduced_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID + "-smoke", arch="mace", d_in=8,
                     d_hidden=16, d_out=1, n_layers=2, l_max=2,
                     correlation=3, mace_n_rbf=4, cutoff=10.0)
