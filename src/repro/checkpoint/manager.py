"""The one checkpoint stack: atomic fsync'd step trees + the publish
protocol readers subscribe to.

Every durable artifact in the repo goes through this module — the train
driver's (params, opt, step) trees, the serve loop's full snapshot state
(`core/snapshot.save_snapshot` routes here), and the replica tier's
publish/subscribe protocol (`launch/replica.py`). One on-disk format,
one step-discovery rule, one prune policy.

Fault-tolerance contract (DESIGN.md §4, §9):

  * `save(step)` writes every leaf as .npy under a temp dir, fsyncs each
    leaf and the directory, then atomically renames to ``step_<n>`` — a
    preempted writer never corrupts the newest checkpoint, and a rename
    that survives a crash implies the leaves under it are durable;
  * `restore()` finds the newest complete checkpoint and places each
    leaf with the *current* mesh/sharding — restoring a 512-chip
    checkpoint onto 256 chips (or CPU) re-shards transparently;
  * `publish(step)` flips the ``CURRENT`` pointer file to a saved step
    via the same write-fsync-rename dance. ``CURRENT`` is the
    single-writer/many-reader seam of the replica tier: readers map
    whatever step it names (`load_leaves(mmap=True)` — the labelling
    planes are never copied on the host) and only ever observe fully
    durable steps, because the pointer is flipped *after* the step's
    own fsync'd rename;
  * `prune(keep=)` never removes the published step, so a reader that
    restarts mid-prune always finds the snapshot ``CURRENT`` names.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

CURRENT = "CURRENT"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return leaves, treedef


def _key_str(path) -> str:
    return "__".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)


def _fsync_path(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def write_json_atomic(path: str, payload: dict) -> None:
    """Write-fsync-rename a small JSON record (pointer files, acks).

    A reader polling `path` sees either the old complete record or the
    new complete record, never a torn write; after the rename returns,
    the record survives a crash (file fsync'd before, directory after).
    """
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_path(os.path.dirname(path) or ".")


def read_json(path: str) -> dict | None:
    """Best-effort read of an atomic JSON record (None if absent)."""
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        # A JSONDecodeError can only be a partially-visible non-atomic
        # write (e.g. NFS); the poller retries on its next turn.
        return None


def save(ckpt_dir: str, step: int, tree) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _flatten(tree)
    manifest = []
    for path, leaf in leaves:
        name = _key_str(path)
        arr = np.asarray(jax.device_get(leaf))
        leaf_path = os.path.join(tmp, name + ".npy")
        np.save(leaf_path, arr)
        _fsync_path(leaf_path)
        manifest.append(name)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": manifest}, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    _fsync_path(tmp)
    os.rename(tmp, final)  # atomic commit
    _fsync_path(ckpt_dir)
    return final


def step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step}")


def step_manifest(ckpt_dir: str, step: int) -> dict | None:
    return read_json(os.path.join(step_dir(ckpt_dir, step), "manifest.json"))


def latest_step(ckpt_dir: str) -> int | None:
    """Newest complete step on disk (scan; `current_step` for published)."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, d, "manifest.json")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def load_leaves(ckpt_dir: str, step: int, names: tuple[str, ...] | None = None,
                mmap: bool = False) -> dict[str, np.ndarray]:
    """Load (a subset of) a step's leaves by name.

    `mmap=True` maps each array copy-free (`np.load(mmap_mode="r")`) —
    the replica readers' path: N readers of one published labelling
    share one page-cache copy of the planes instead of N host copies.
    """
    d = step_dir(ckpt_dir, step)
    man = step_manifest(ckpt_dir, step)
    if man is None:
        raise FileNotFoundError(f"no complete checkpoint at {d}")
    want = man["leaves"] if names is None else list(names)
    mode = "r" if mmap else None
    out = {}
    for name in want:
        p = os.path.join(d, name + ".npy")
        if not os.path.exists(p):
            raise FileNotFoundError(f"checkpoint {d} lacks leaf {name!r}")
        out[name] = np.load(p, mmap_mode=mode)
    return out


def restore(ckpt_dir: str, tree_like, shardings=None, step: int | None = None):
    """Restore into the structure of `tree_like`; optionally place each
    leaf with `shardings` (same pytree structure) — elastic re-shard."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = step_dir(ckpt_dir, step)
    leaves, treedef = _flatten(tree_like)
    sh_leaves = None
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "addressable_devices"))
    out = []
    for i, (path, leaf) in enumerate(leaves):
        arr = np.load(os.path.join(d, _key_str(path) + ".npy"))
        if sh_leaves is not None:
            out.append(jax.device_put(arr, sh_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree_like), out), step


# ---------------------------------------------------------------------------
# Publish protocol (the replica tier's single-writer/many-reader seam)
# ---------------------------------------------------------------------------

def publish(ckpt_dir: str, step: int, extra: dict | None = None) -> dict:
    """Flip the CURRENT pointer to a saved step, durably.

    The step must already be committed by `save` (its rename + fsync
    happened-before this call), so a reader that observes the new
    pointer can always map the step it names — the crash-safety half of
    the staleness ≤ 1 contract (DESIGN.md §9). `extra` rides along in
    the pointer record (the updater stores the run's base config hash).
    """
    if step_manifest(ckpt_dir, step) is None:
        raise FileNotFoundError(
            f"cannot publish step {step}: no complete checkpoint under "
            f"{step_dir(ckpt_dir, step)}")
    record = {"version": int(step), "path": f"step_{step}"}
    record.update(extra or {})
    write_json_atomic(os.path.join(ckpt_dir, CURRENT), record)
    return record


def read_current(ckpt_dir: str) -> dict | None:
    """The published pointer record, or None before the first publish."""
    return read_json(os.path.join(ckpt_dir, CURRENT))


def current_step(ckpt_dir: str) -> int | None:
    rec = read_current(ckpt_dir)
    return int(rec["version"]) if rec is not None else None


def prune(ckpt_dir: str, keep: int = 3) -> None:
    """Remove all but the newest `keep` steps — and never anything from
    the published step forward.

    A reader (re)starting from CURRENT must always find the step the
    pointer names, however old the pointer is relative to the writer —
    and a reader that loaded CURRENT and is walking forward to the head
    must find every intermediate step too (the staleness ≤ 1 catch-up
    path in DESIGN.md §9). So the whole range [CURRENT, latest] is
    protected, not just the one step the pointer names: protecting only
    ``s == protected`` would let an aggressive ``keep`` delete a step
    between the pointer and the head out from under a catching-up
    reader.
    """
    if not os.path.isdir(ckpt_dir):
        return
    protected = current_step(ckpt_dir)
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_"))
    for s in steps[:-keep] if keep > 0 else steps:
        if protected is not None and s >= protected:
            continue
        shutil.rmtree(step_dir(ckpt_dir, s), ignore_errors=True)
