from repro.kernels.minplus import kernel, ops, ref  # noqa: F401
