"""Serving-tick latency trajectory: backend × mesh, the CI bench preset.

The scale story of this repo lives or dies on two numbers per tick — the
batch-update latency and the query-batch latency — across the four
backend × mesh configurations that PRs 1–3 built:

    ticks/<dataset>/<backend>/<mesh>/construct   (one-off, seconds→us)
    ticks/<dataset>/<backend>/<mesh>/update      (median per-tick)
    ticks/<dataset>/<backend>/<mesh>/query       (median per-tick)

PR 4 adds the *serving-pipeline* trajectory: the open-loop query stream
of `launch/serve.py` measured under concurrent update load, synchronous
vs pipelined (DESIGN.md §5):

    serve/<dataset>/<backend>/<mode>/q_p50|q_p95|q_p99   (per-query s→us)
    serve/<dataset>/<backend>/<mode>/update              (min steady tick)
    serve/<dataset>/<backend>/<mode>/staleness           (mean versions
                                                          behind head —
                                                          telemetry, not
                                                          a latency)

where mode ∈ {sync, pipeline}. The pipeline's whole point shows up here:
sync q_p99 tracks the update latency (queries queue behind the monolithic
dispatch), pipeline q_p99 tracks one chunk + one microbatch.

PR 5 adds mode `growth` — the pure-insertion `growth` scenario run
pipelined with grow-in-place enabled (`--capacity` below the stream's
final size, DESIGN.md §6), its capacity sized so the geometric growth
lands on a steady-state tick: the q percentiles price serving *through*
the growth retrace/retile, and the row's `derived` field records the
growth count and capacity trajectory.

PR 6 adds the autotuner trajectory (DESIGN.md §7): the pallas tick and
serve rows run with ``autotune=True`` (the engine measures its candidate
configs once per snapshot shape and serves the winner — the winning impl
is recorded in each row's ``derived``), pipelined serve rows run the
fused megakernel chunks, and three new row families pin the jnp-vs-tuned
comparison directly:

    tune/<dataset>/jnp      reference sweep, steady min-of-k
    tune/<dataset>/pallas   tuned winner, same wave, same stat
    tune/crossover          telemetry: smallest benched vertex count
                            where the tuned config won (unit=vertices)

PR 7 adds the weighted-metric trajectory (DESIGN.md §8): tick rows on
the weighted road grid (``ticks/road_2k/<backend>/none``) and the
``traffic`` serving rows (``serve/road_2k/<backend>/traffic``) — weight
churn dominates each batch, every 4th tick is weight-change-only, and
the Dijkstra-exact answers ride the same percentile contract.

PR 8 adds the replica-tier saturation trajectory (DESIGN.md §9): a real
multi-process topology — one updater publishing versions, R mmap'd
reader replicas behind the coalescing router of ``launch/replica.py`` —
rammed with an open-loop client stream at a rising qps ladder until the
p99 breaks the SLO:

    serve/<dataset>/<backend>/max_qps_r1    sustained qps, 1 reader
    serve/<dataset>/<backend>/max_qps_r2    sustained qps, 2 readers

(``unit=qps;better=higher`` — compare.py gates these with the inverted
ratio; r2/r1 is the throughput the second reader buys.)

PR 10 adds the frontier-proportional trajectory (DESIGN.md §10):

    ticks/<dataset>/<backend>/footprint_small   quiet-tick trickle,
                                                no-retile op mix
    ticks/<dataset>/<backend>/footprint_large   full mixed batch

both timed with the frontier mode on (each row's ``derived`` records
the same tick stream's full-sweep latency as ``fullsweep_us``) — the
scale-with-batch-footprint claim in two gated rows, on both the
hub-dominated BA graph and the planar road grid where change stays
local.

Rows follow the ``name,us_per_call,derived`` contract of benchmarks/run.py;
``python -m benchmarks.run --preset quick --json BENCH_pr5.json`` persists
them in the bench-trajectory JSON format that `benchmarks/compare.py`
gates against the committed `benchmarks/baseline.json` (>25% regressions
on any gated tick latency *or* serve percentile fail the CI `bench` job).

The quick preset is sized for shared CI runners: one small dataset, a few
ticks, the degenerate host mesh on however many devices the runner
exposes. The point is the *trajectory* (same shapes every PR), not
absolute hardware truth.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BA_PARAMS, DATASETS, ROAD_PARAMS, emit
from repro.graphs import generators as gen
from repro.graphs.coo import apply_batch, from_edges, make_batch
from repro.core.batch import batchhl_update
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.engine import RelaxEngine
from repro.core.query import batched_query
from repro.core.shard import (shard_batched_query, shard_batchhl_update,
                              shard_build_labelling)
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import ServeConfig, ServeLoop

#: datasets the serve loop can regenerate itself (it builds its own BA
#: graph from `common.BA_PARAMS` — one source of truth with DATASETS).
SERVE_DATASETS = {"ba_2k"}


def _tick_loop(name: str, g0, landmarks, edges, backend: str, mesh,
               ticks: int, batch_size: int, queries: int,
               block_v: int, tile_shards: int,
               autotune: bool = False) -> list[str]:
    n = g0.n
    engine = RelaxEngine(backend=backend, block_v=block_v,
                         shards=tile_shards, autotune=autotune)
    plan = engine.prepare(g0)

    t0 = time.time()
    if mesh is None:
        lab = build_labelling(g0, landmarks, plan=plan)
    else:
        lab = shard_build_labelling(mesh, g0, landmarks, plan=plan)
    jax.block_until_ready(lab.dist)
    rows = [emit(f"{name}/construct", time.time() - t0, f"R={len(landmarks)}")]

    rng = np.random.default_rng(11)
    # Weighted datasets carry an [E, 3] edge array; the host-side fold
    # only tracks membership (weights live in the device graph).
    g, cur_edges = g0, (edges[:, :2] if edges.shape[1] > 2 else edges)
    t_upd, t_q = [], []
    for tick in range(ticks):
        ups = gen.random_batch_updates(cur_edges, n, n_ins=batch_size // 2,
                                       n_del=batch_size // 2,
                                       seed=500 + tick)
        batch = make_batch(ups, pad_to=batch_size)
        has_ins = any(not d for (_, _, d) in ups)
        t0 = time.time()
        g_next = apply_batch(g, batch)
        plan = engine.prepare(g_next, topology_changed=has_ins)
        if mesh is None:
            g, lab, aff = batchhl_update(g, batch, lab, improved=True,
                                         plan=plan, g_new=g_next)
        else:
            g, lab, aff = shard_batchhl_update(mesh, g, batch, lab,
                                               improved=True, plan=plan,
                                               g_new=g_next)
        jax.block_until_ready(lab.dist)
        t_upd.append(time.time() - t0)

        qs = jnp.asarray(rng.integers(0, n, queries), jnp.int32)
        qt = jnp.asarray(rng.integers(0, n, queries), jnp.int32)
        t0 = time.time()
        if mesh is None:
            d = batched_query(g, lab, qs, qt, plan=plan)
        else:
            d = shard_batched_query(mesh, g, lab, qs, qt, plan=plan)
        jax.block_until_ready(d)
        t_q.append(time.time() - t0)

        # Fold this tick's updates into the edge set for the next one.
        es = {(int(min(u, v)), int(max(u, v))) for u, v in cur_edges}
        for u, v, is_del in ups:
            k = (min(u, v), max(u, v))
            es.discard(k) if is_del else es.add(k)
        cur_edges = np.asarray(sorted(es), np.int32)

    # Min of the steady-state ticks: tick 0 pays compilation and tick 1
    # can pay a second trace (the labelling comes back mesh-sharded after
    # the first update), so both are warmup; min (not median) because a
    # transient load burst on a shared runner inflates several consecutive
    # ticks at once, and the fastest tick is the best estimate of the
    # unloaded latency the gate should track.
    warm = 2 if ticks > 2 else 1 if ticks > 1 else 0
    steady_upd = t_upd[warm:]
    steady_q = t_q[warm:]
    impl = plan.impl if plan is not None and plan.backend == "pallas" \
        else backend
    rows.append(emit(f"{name}/update", float(np.min(steady_upd)),
                     f"stat=min;ticks={ticks};batch={batch_size};"
                     f"impl={impl}"))
    rows.append(emit(f"{name}/query", float(np.min(steady_q)),
                     f"stat=min;ticks={ticks};B={queries};impl={impl}"))
    return rows


def _footprint_rows(ds: str, g0, landmarks, edges, backend: str,
                    ticks: int, block_v: int, tile_shards: int,
                    large: int = 64) -> list[str]:
    """PR 10: the frontier-proportional trajectory (DESIGN.md §10).

    ``ticks/<ds>/<backend>/footprint_small|footprint_large`` time the
    steady-state update tick with the frontier mode on at two batch
    footprints:

    ``footprint_small`` is the quiet-tick trickle — the batch size the
    `bursty` scenario uses between bursts (``max(2, round(0.1*batch))``),
    carrying the no-retile op mix of the production trickle: re-weights
    on weighted datasets (the `traffic` shape), deletions on unweighted
    ones (expiry churn). ``topology_changed=False`` end to end, so the
    tick prices plan+frontier reuse, not retiling.

    ``footprint_large`` is the preset's full mixed batch over the whole
    vertex range — the same shape as the main tick rows, with the
    frontier on. At that footprint the density fallback fires and the
    row tracks the bookkeeping overhead of carrying the bitmaps.

    The pair is the scale-with-footprint claim in two numbers. Each
    row's ``derived`` also records the full-sweep latency of the *same*
    tick stream (``fullsweep_us=``), so the masked win — or, on
    hub-dominated graphs where one block-hop saturates the bitmap, the
    masked *overhead* — is auditable per row rather than only against
    the committed baseline trajectory.
    """
    n = g0.n
    weighted = edges.shape[1] > 2
    small = max(2, round(large * 0.1))
    rows = []
    for frontier in (True, False):
        engine = RelaxEngine(backend=backend, block_v=block_v,
                             shards=tile_shards, frontier=frontier,
                             autotune=(backend == "pallas"))
        lab0 = build_labelling(g0, landmarks, plan=engine.prepare(g0))
        jax.block_until_ready(lab0.dist)
        for tag, bs, trickle in (("footprint_small", small, True),
                                 ("footprint_large", large, False)):
            g, lab = g0, lab0
            cur = edges[:, :2] if weighted else edges
            t_upd = []
            for tick in range(ticks):
                # Same deterministic stream for both engines (seed only).
                if trickle and weighted:
                    ups = gen.random_batch_updates(cur, n, n_ins=0,
                                                   n_del=0, n_rew=bs,
                                                   max_weight=8,
                                                   seed=900 + tick)
                elif trickle:
                    ups = gen.random_batch_updates(cur, n, n_ins=0,
                                                   n_del=bs,
                                                   seed=900 + tick)
                else:
                    ups = gen.random_batch_updates(cur, n, n_ins=bs // 2,
                                                   n_del=bs // 2,
                                                   seed=900 + tick)
                batch = make_batch(ups, pad_to=bs)
                # Trickle ops never consume or free slot pairs in a way
                # the tiling sees; only insertions force a retile.
                has_ins = (not trickle) and any(not u[2] for u in ups)
                t0 = time.time()
                g_next = apply_batch(g, batch)
                plan = engine.prepare(g_next, topology_changed=has_ins)
                g, lab, _ = batchhl_update(g, batch, lab, improved=True,
                                           plan=plan, g_new=g_next)
                jax.block_until_ready(lab.dist)
                t_upd.append(time.time() - t0)
                if not (trickle and weighted):
                    # Fold membership churn (re-weights don't change it).
                    es = {(int(min(u, v)), int(max(u, v))) for u, v in cur}
                    for u, v, is_del, *_ in ups:
                        k = (min(u, v), max(u, v))
                        es.discard(k) if is_del else es.add(k)
                    cur = np.asarray(sorted(es), np.int32)
            warm = 2 if ticks > 2 else 1 if ticks > 1 else 0
            rows.append((tag, bs, trickle, frontier,
                         float(np.min(t_upd[warm:]))))
    by_tag = {}
    for tag, bs, trickle, frontier, m in rows:
        by_tag.setdefault(tag, {})[frontier] = (bs, trickle, m)
    out = []
    for tag, d in by_tag.items():
        bs, trickle, masked_s = d[True]
        _, _, full_s = d[False]
        ops = ("rew" if weighted else "del") if trickle else "mixed"
        out.append(emit(
            f"ticks/{ds}/{backend}/{tag}", masked_s,
            f"stat=min;ticks={ticks};batch={bs};ops={ops};frontier=on;"
            f"fullsweep_us={full_s * 1e6:.1f}"))
    return out


def _tune_rows(ds: str, g, tile_shards: int,
               block_v: int) -> tuple[list[str], float]:
    """The `tune/` rows: one autotuner measurement per dataset shape.

    `tune/<ds>/jnp` is the reference wave's steady latency and
    `tune/<ds>/pallas` the tuned winner's (both min-of-k after warmup —
    `autotune.measure_compiled`), so the pair *is* the jnp-vs-tuned
    comparison the PR-6 acceptance reads. The crossover — smallest
    benched vertex count where the tuned config wins — is recorded in
    the `derived` field of `tune/crossover` (its value is the vertex
    count, unit=vertices: telemetry like the staleness rows, sub-min-us
    by construction so the compare gate never flakes on it moving).
    """
    from repro.core import autotune as at

    res = at.tune(g, shards=tile_shards, block_v=block_v, iters=5)
    cfg = res.config
    speed = res.jnp_us / res.steady_us if res.steady_us else float("inf")
    info = f"R=8;cap={g.src.shape[0]};stat=min"
    rows = [emit(f"tune/{ds}/jnp", res.jnp_us / 1e6, info),
            emit(f"tune/{ds}/pallas", res.steady_us / 1e6,
                 f"impl={cfg.impl};block_v={cfg.block_v};"
                 f"block_e={cfg.block_e};tile_shards={cfg.tile_shards};"
                 f"compile_us={res.compile_us:.1f};speedup={speed:.2f}x;"
                 f"stat=min")]
    return rows, speed


def _serve_loop(name: str, n: int, deg: int, backend: str, mode: str,
                ticks: int, batch_size: int, queries: int, landmarks: int,
                block_v: int, tile_shards: int, qps: float,
                microbatch: int, capacity: int | None = None,
                autotune: bool = False, fused: bool = False,
                scenario: str | None = None,
                graph: str = "ba") -> list[str]:
    """One ServeLoop run → the serve/ percentile + staleness rows.

    Percentiles are computed over the steady-state ticks only (the same
    warmup convention as `_tick_loop`: tick 0 pays compilation, tick 1
    can pay a reshard retrace), per query, arrival → answered.

    mode "growth" runs the pure-insertion `growth` scenario pipelined
    with grow-in-place enabled from a deliberately small `capacity`, so
    the row tracks the cost of serving *through* a growth event (shape
    retrace + retile on the growth tick) rather than steady state only.
    """
    cfg = ServeConfig(n=n, deg=deg, graph=graph, landmarks=landmarks,
                      batches=ticks,
                      batch_size=batch_size, queries=queries, qps=qps,
                      microbatch=microbatch, pipeline=(mode != "sync"),
                      scenario=scenario or (
                          "growth" if mode == "growth" else "mixed"),
                      capacity=capacity, grow=(mode == "growth"),
                      backend=backend, block_v=block_v,
                      tile_shards=tile_shards, autotune=autotune,
                      fused=fused, quiet=True)
    rep = ServeLoop(cfg).run()
    warm = 2 if ticks > 2 else 1 if ticks > 1 else 0
    mbs = [m for m in rep.microbatches if m.tick >= warm]
    lat = np.concatenate([m.latencies for m in mbs])
    stale = float(np.concatenate(
        [np.full(m.latencies.shape, m.staleness) for m in mbs]).mean())
    upd = min(t.update_s for t in rep.ticks if t.tick >= warm)
    info = (f"ticks={ticks};Q={queries};qps={qps:g};mb={microbatch};"
            f"chunk={cfg.chunk_sweeps}")
    if mode == "growth":
        info += (f";growths={len(rep.growth)};cap={capacity}->"
                 f"{rep.final.graph.capacity}")
    rows = [emit(f"{name}/q_p50", float(np.percentile(lat, 50)), info),
            emit(f"{name}/q_p95", float(np.percentile(lat, 95)), info),
            emit(f"{name}/q_p99", float(np.percentile(lat, 99)), info),
            emit(f"{name}/update", upd, f"stat=min;{info}")]
    # Telemetry, not a latency: the value is mean versions-behind-head.
    row = f"{name}/staleness,{stale:.4f},unit=versions;{info}"
    print(row)
    rows.append(row)
    return rows


def _saturation_loop(name: str, n: int, deg: int, backend: str,
                     readers: int, landmarks: int, block_v: int,
                     tile_shards: int, microbatch: int,
                     slo_ms: float = 50.0, ticks: int = 3,
                     batch_size: int = 64,
                     autotune: bool = False) -> list[str]:
    """The replica-tier saturation row: ramp qps until p99 breaks the SLO.

    Deploys a real 1-updater + `readers`-reader topology (separate
    processes, the `launch/replica.py` router in front), lets the
    updater finish its ticks so the ramp measures serving alone, then
    drives open-loop client streams at a ×1.3 qps ladder. The row's
    value is the last rate the topology sustained with p99 <= `slo_ms`
    and <1% admission rejections — ``unit=qps;better=higher``, which
    `benchmarks/compare.py` gates with the inverted ratio. The ladder's
    coarseness is deliberate: one step of runner noise (−23%) stays
    inside the gate's 25% budget.
    """
    import shutil
    import tempfile

    from repro.launch import replica
    from repro.launch.config import (EngineSpec, GraphSpec, ServeSpec,
                                     StreamSpec, TopologySpec)

    publish_dir = tempfile.mkdtemp(prefix="repro_sat_")
    spec = ServeSpec(
        graph=GraphSpec(n=n, deg=deg, landmarks=landmarks),
        engine=EngineSpec(backend=backend, block_v=block_v,
                          tile_shards=tile_shards, autotune=autotune),
        stream=StreamSpec(batches=ticks, batch_size=batch_size, queries=0,
                          microbatch=microbatch, quiet=True),
        topology=TopologySpec(readers=readers, slo_ms=slo_ms),
    )
    topo = replica.ReplicaTopology(spec, publish_dir)
    max_qps, p99_at_max = 0.0, 0.0
    try:
        topo.start()
        topo.updater.wait(timeout=300)  # ramp against a quiesced tier
        qps = 200.0
        while qps <= 8200.0:
            total = min(int(qps * 1.2), 4000)
            rep = replica.stream_queries(
                spec, topo, total, qps,
                workers=min(64, max(8, int(qps / 40))))
            p99 = rep.latency_percentiles()["p99"]
            if (p99 * 1e3 > slo_ms or not rep.answers
                    or rep.rejected > 0.01 * total):
                break
            max_qps, p99_at_max = qps, p99
            qps *= 1.3
    finally:
        topo.stop()
        shutil.rmtree(publish_dir, ignore_errors=True)
    row = (f"{name},{max_qps:.1f},unit=qps;better=higher;"
           f"readers={readers};slo_ms={slo_ms:g};mb={microbatch};"
           f"p99_at_max={p99_at_max * 1e3:.1f}ms")
    print(row)
    return [row]


def run(datasets=("ba_2k",), backends=("jnp", "pallas"),
        meshes=("none", "host"), ticks: int = 6, batch_size: int = 64,
        queries: int = 128, landmarks: int = 16, block_v: int = 256,
        tile_shards: int = 2, serve_modes=("sync", "pipeline"),
        qps: float = 2000.0, microbatch: int = 32) -> list[str]:
    rows = []
    crossover = None
    for ds in datasets:
        edges = DATASETS[ds]()
        n = int(edges[:, :2].max()) + 1
        cap = edges.shape[0] + ticks * batch_size + 64
        g0 = from_edges(n, edges, cap)
        lms = select_landmarks_by_degree(g0, landmarks)
        # The jnp-vs-tuned sweep comparison at this exact bench shape
        # (capacity slack included — that slack is where the tuned
        # sorted impl's win comes from), plus crossover bookkeeping.
        trows, speedup = _tune_rows(ds, g0, tile_shards, block_v)
        rows += trows
        if speedup > 1.0 and (crossover is None or n < crossover):
            crossover = n
        for backend in backends:
            for mesh_name in meshes:
                mesh = make_host_mesh() if mesh_name == "host" else None
                # pallas rows run autotuned: the row tracks the best
                # config the tuner finds on this runner, not a fixed
                # hand-picked tiling (impl lands in `derived`).
                rows += _tick_loop(f"ticks/{ds}/{backend}/{mesh_name}",
                                   g0, lms, edges, backend, mesh, ticks,
                                   batch_size, queries, block_v,
                                   tile_shards,
                                   autotune=(backend == "pallas"))
            # PR 10: frontier-proportional update rows (DESIGN.md §10) —
            # tick cost vs batch footprint with change propagation on.
            rows += _footprint_rows(ds, g0, lms, edges, backend, ticks,
                                    block_v, tile_shards,
                                    large=batch_size)
    # Telemetry, not a latency: smallest benched vertex count where the
    # tuned pallas config beat the jnp reference (0 = none did).
    row = (f"tune/crossover,{crossover or 0},unit=vertices;"
           f"datasets={'+'.join(datasets)}")
    print(row)
    rows.append(row)
    # The serving-pipeline trajectory: unsharded sync vs pipeline per
    # backend (the mesh × pipeline composition is smoke-tested by the CI
    # `mesh` job; benching it here would double the preset's runtime),
    # plus the grow-in-place trajectory: the `growth` scenario started
    # at a capacity that overflows on a *steady-state* tick, so the row
    # tracks query latency through the growth retrace/retile
    # (DESIGN.md §6) instead of only warm steady ticks.
    for ds in datasets:
        if ds not in SERVE_DATASETS:
            continue
        n, deg = BA_PARAMS[ds]
        e0 = DATASETS[ds]().shape[0]
        for backend in backends:
            for mode in serve_modes:
                # pallas serve rows run autotuned, and the pipelined mode
                # uses the fused megakernel chunks (sync updates are the
                # monolithic dispatch — nothing to fuse). The growth row
                # stays untuned: a re-tune fires inside every growth
                # event (capacity changes the table key), and putting
                # tuner compiles on the serving path would make the row
                # track compile noise instead of the growth cost.
                rows += _serve_loop(f"serve/{ds}/{backend}/{mode}", n, deg,
                                    backend, mode, ticks, batch_size,
                                    queries, landmarks, block_v,
                                    tile_shards, qps, microbatch,
                                    autotune=(backend == "pallas"),
                                    fused=(mode == "pipeline"))
            rows += _serve_loop(f"serve/{ds}/{backend}/growth", n, deg,
                                backend, "growth", ticks, batch_size,
                                queries, landmarks, block_v, tile_shards,
                                qps, microbatch,
                                capacity=e0 + 7 * batch_size // 2,
                                fused=True)
            # PR 8: the replica-tier saturation trajectory (DESIGN.md
            # §9) — how much client qps a real multi-process topology
            # (1 updater + R readers behind the coalescing router)
            # sustains inside the p99 SLO, for R=1 and R=2. The pair is
            # the scale-out story in two numbers: r2/r1 is the
            # throughput the second reader actually buys.
            for r in (1, 2):
                rows += _saturation_loop(
                    f"serve/{ds}/{backend}/max_qps_r{r}", n, deg,
                    backend, r, landmarks, block_v, tile_shards,
                    microbatch, autotune=(backend == "pallas"))
    # The weighted trajectory (DESIGN.md §8): tick rows on the road grid
    # (mesh composition is covered by the ba rows above; benching it
    # again on road would double the preset) and the `traffic` serving
    # rows — weight churn dominates each batch and every 4th tick is
    # weight-change-only, so the update row prices the no-retile path.
    road_edges = DATASETS["road_2k"]()
    road_n = int(road_edges[:, :2].max()) + 1
    road_cap = road_edges.shape[0] + ticks * batch_size + 64
    g0r = from_edges(road_n, road_edges, road_cap)
    lms_r = select_landmarks_by_degree(g0r, landmarks)
    for backend in backends:
        rows += _tick_loop(f"ticks/road_2k/{backend}/none", g0r, lms_r,
                           road_edges, backend, None, ticks, batch_size,
                           queries, block_v, tile_shards,
                           autotune=(backend == "pallas"))
        # Frontier footprint rows on the road grid too: the planar block
        # graph is where change propagation stays local (DESIGN.md §10)
        # and the trickle is the traffic scenario's weight-only tick.
        rows += _footprint_rows("road_2k", g0r, lms_r, road_edges,
                                backend, ticks, block_v, tile_shards,
                                large=batch_size)
        rows += _serve_loop(f"serve/road_2k/{backend}/traffic",
                            ROAD_PARAMS["road_2k"][0], 3, backend,
                            "pipeline", ticks, batch_size, queries,
                            landmarks, block_v, tile_shards, qps,
                            microbatch, autotune=(backend == "pallas"),
                            fused=True, scenario="traffic", graph="road")
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
