"""Pure-Python oracle for BatchHL invariants (host-side, test-only).

Implements from first principles (plain BFS / Dijkstra / DP, no JAX):
  * exact distances — BFS for the hop-count metric, binary-heap Dijkstra
    for the weighted metric (adjacency `{u: {v: w}}`, weights >= 1),
  * landmark lengths d^L(r, v) = (distance, hub flag) with the paper's
    True < False ordering (flag True iff ANY shortest r->v path passes
    through a landmark other than r; endpoints count, r excluded) — the
    weighted predecessor test is dist[u] + w(u, v) == dist[v],
  * the unique minimal highway-cover labelling,
  * affected / LD-affected sets (Definitions 5.1 and 5.12).
"""
from __future__ import annotations

import heapq
from collections import deque

INF = float("inf")


def bfs_dist(adj: dict[int, set[int]], n: int, src: int) -> list[float]:
    dist = [INF] * n
    dist[src] = 0
    q = deque([src])
    while q:
        u = q.popleft()
        for w in adj[u]:
            if dist[w] == INF:
                dist[w] = dist[u] + 1
                q.append(w)
    return dist


def landmark_length(adj: dict[int, set[int]], n: int, landmarks: list[int],
                    r: int) -> tuple[list[float], list[bool]]:
    """d^L(r, ·): (distance, hub flag) per vertex."""
    others = set(landmarks) - {r}
    dist = bfs_dist(adj, n, r)
    order = sorted((v for v in range(n) if dist[v] < INF),
                   key=lambda v: dist[v])
    hub = [False] * n
    for v in order:
        if v == r:
            continue
        if v in others:
            hub[v] = True
            continue
        hub[v] = any(hub[u] for u in adj[v]
                     if dist[u] == dist[v] - 1)
    return dist, hub


def minimal_labelling(adj: dict[int, set[int]], n: int,
                      landmarks: list[int]):
    """Returns (dist[R][V], hub[R][V], highway[R][R], label_mask[R][V])."""
    r_count = len(landmarks)
    dist, hub, mask = [], [], []
    for r in landmarks:
        d, h = landmark_length(adj, n, landmarks, r)
        dist.append(d)
        hub.append(h)
        mask.append([d[v] < INF and not h[v] and v not in landmarks
                     for v in range(n)])
    highway = [[dist[i][landmarks[j]] for j in range(r_count)]
               for i in range(r_count)]
    return dist, hub, highway, mask


def affected_set(adj_old, adj_new, n: int, r: int) -> set[int]:
    """Definition 5.1: P_G(r,v) != P_G'(r,v). We compare the shortest-path
    DAGs (distance + predecessor sets at shortest level), which determine
    the shortest-path sets exactly."""
    d0 = bfs_dist(adj_old, n, r)
    d1 = bfs_dist(adj_new, n, r)
    aff = set()
    # Process by level so predecessors are classified before dependents.
    for v in sorted(range(n), key=lambda x: min(d0[x], d1[x])):
        if v == r:
            continue
        if d0[v] != d1[v]:
            aff.add(v)
            continue
        if d0[v] == INF:
            continue
        pred0 = {u for u in adj_old[v] if d0[u] == d0[v] - 1}
        pred1 = {u for u in adj_new[v] if d1[u] == d1[v] - 1}
        if pred0 != pred1 or any(u in aff for u in pred0 | pred1):
            aff.add(v)
    return aff


def ld_affected_set(adj_old, adj_new, n: int, landmarks: list[int],
                    r: int) -> set[int]:
    """Definition 5.12 via Lemma 5.15: d^L_G(r,v) != d^L_G'(r,v)."""
    d0, h0 = landmark_length(adj_old, n, landmarks, r)
    d1, h1 = landmark_length(adj_new, n, landmarks, r)
    out = set()
    for v in range(n):
        if d0[v] != d1[v]:
            out.add(v)
        elif d0[v] < INF and h0[v] != h1[v]:
            out.add(v)
    return out


def apply_updates(adj: dict[int, set[int]], updates) -> dict[int, set[int]]:
    """updates: list of (u, v, is_del). Returns a new adjacency dict."""
    new = {v: set(s) for v, s in adj.items()}
    for u, v, is_del in updates:
        if is_del:
            new[u].discard(v)
            new[v].discard(u)
        else:
            new[u].add(v)
            new[v].add(u)
    return new


def pair_distance(adj, n: int, s: int, t: int) -> float:
    return bfs_dist(adj, n, s)[t]


# --- weighted oracle (Dijkstra; adjacency {u: {v: w}}, weights >= 1) --------

def dijkstra_dist(wadj: dict[int, dict[int, int]], n: int,
                  src: int) -> list[float]:
    """Single-source shortest paths under positive integer edge weights."""
    dist = [INF] * n
    dist[src] = 0
    heap = [(0, src)]
    while heap:
        d, u = heapq.heappop(heap)
        if d > dist[u]:
            continue
        for v, w in wadj.get(u, {}).items():
            nd = d + w
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(heap, (nd, v))
    return dist


def unit_wadj(adj: dict[int, set[int]]) -> dict[int, dict[int, int]]:
    """Lift an unweighted adjacency to the weighted form with w ≡ 1."""
    return {u: {v: 1 for v in s} for u, s in adj.items()}


def landmark_length_w(wadj: dict[int, dict[int, int]], n: int,
                      landmarks: list[int],
                      r: int) -> tuple[list[float], list[bool]]:
    """Weighted d^L(r, ·): (distance, hub flag) per vertex. The hub DP
    visits vertices in distance order; u precedes v on a shortest path
    iff dist[u] + w(u, v) == dist[v]."""
    others = set(landmarks) - {r}
    dist = dijkstra_dist(wadj, n, r)
    order = sorted((v for v in range(n) if dist[v] < INF),
                   key=lambda v: dist[v])
    hub = [False] * n
    for v in order:
        if v == r:
            continue
        if v in others:
            hub[v] = True
            continue
        hub[v] = any(hub[u] for u, w in wadj.get(v, {}).items()
                     if dist[u] + w == dist[v])
    return dist, hub


def minimal_labelling_w(wadj: dict[int, dict[int, int]], n: int,
                        landmarks: list[int]):
    """Weighted (dist[R][V], hub[R][V], highway[R][R], label_mask[R][V])."""
    r_count = len(landmarks)
    dist, hub, mask = [], [], []
    for r in landmarks:
        d, h = landmark_length_w(wadj, n, landmarks, r)
        dist.append(d)
        hub.append(h)
        mask.append([d[v] < INF and not h[v] and v not in landmarks
                     for v in range(n)])
    highway = [[dist[i][landmarks[j]] for j in range(r_count)]
               for i in range(r_count)]
    return dist, hub, highway, mask


def apply_updates_w(wadj: dict[int, dict[int, int]],
                    updates) -> dict[int, dict[int, int]]:
    """updates: (u, v, op[, w]) with op 0=insert, 1=delete, 2=reweight
    (insert and reweight default to w=1). Returns a new weighted
    adjacency; reweighting an absent edge inserts it, matching
    `coo.apply_batch`'s slot semantics only for edges that exist — tests
    only reweight live edges, so keep the simple set-the-weight rule."""
    new = {v: dict(d) for v, d in wadj.items()}
    for up in updates:
        u, v, op = up[0], up[1], int(up[2])
        w = int(up[3]) if len(up) > 3 else 1
        if op == 1:
            new[u].pop(v, None)
            new[v].pop(u, None)
        else:
            new[u][v] = w
            new[v][u] = w
    return new


def pair_distance_w(wadj, n: int, s: int, t: int) -> float:
    return dijkstra_dist(wadj, n, s)[t]


# --- directed-graph oracle (paper §6) ---------------------------------------

def bfs_dist_directed(adj_out: dict[int, set[int]], n: int,
                      src: int) -> list[float]:
    dist = [INF] * n
    dist[src] = 0
    q = deque([src])
    while q:
        u = q.popleft()
        for w in adj_out[u]:
            if dist[w] == INF:
                dist[w] = dist[u] + 1
                q.append(w)
    return dist


def reverse_adj(adj_out: dict[int, set[int]], n: int) -> dict[int, set[int]]:
    rev: dict[int, set[int]] = {v: set() for v in range(n)}
    for u, outs in adj_out.items():
        for v in outs:
            rev[v].add(u)
    return rev


def landmark_length_directed(adj_out, n, landmarks, r):
    """d^L(r → ·) along arcs: (distance, hub flag) per vertex."""
    others = set(landmarks) - {r}
    dist = bfs_dist_directed(adj_out, n, r)
    rev = reverse_adj(adj_out, n)
    order = sorted((v for v in range(n) if dist[v] < INF),
                   key=lambda v: dist[v])
    hub = [False] * n
    for v in order:
        if v == r:
            continue
        if v in others:
            hub[v] = True
            continue
        hub[v] = any(hub[u] for u in rev[v] if dist[u] == dist[v] - 1)
    return dist, hub


def minimal_labelling_directed(adj_out, n, landmarks):
    """(dist, hub, highway, mask) for one directed plane."""
    r_count = len(landmarks)
    dist, hub, mask = [], [], []
    for r in landmarks:
        d, h = landmark_length_directed(adj_out, n, landmarks, r)
        dist.append(d)
        hub.append(h)
        mask.append([d[v] < INF and not h[v] and v not in landmarks
                     for v in range(n)])
    highway = [[dist[i][landmarks[j]] for j in range(r_count)]
               for i in range(r_count)]
    return dist, hub, highway, mask


def apply_updates_directed(adj_out, updates):
    new = {v: set(s) for v, s in adj_out.items()}
    for u, v, is_del in updates:
        if is_del:
            new[u].discard(v)
        else:
            new[u].add(v)
    return new
