"""The multi-replica serve tier: single-writer updater, N reader
replicas, and a coalescing router — process topology as configuration.

One `ServeLoop` process owns both updates and queries, so every kernel
win is capped by a single process's query throughput. This module splits
the single-writer/many-reader seam that `SnapshotStore` already implies
in-process across *process* boundaries (DESIGN.md §9):

* **updater** — runs the (pipelined, fused, autotuned) batch-update loop
  of `ServeLoop` with the query stream turned off, and commits each
  version *durably*: the step tree is fsync'd and atomically renamed by
  `core/snapshot.save_snapshot`, and only then is the ``CURRENT``
  pointer flipped (`checkpoint/manager.publish`). Before publishing
  version v it waits for every live reader to ack v−1 (the publish
  barrier), so no reader is ever two published versions behind.

* **reader** (×N) — maps the step ``CURRENT`` names (`restore_snapshot`
  with ``mmap=True`` — N readers share one page-cache copy of the
  labelling planes on the host), prepares a query plan, answers query
  microbatches over TCP, and acks each version it flips to via an
  atomic ack record. A reader that crashes is restarted from ``CURRENT``
  and resumes exactly — the pointer only ever names fsync'd steps.

* **router** — the client-facing door: admission control (reject beyond
  ``max_queue`` pending queries), microbatch coalescing (merge small
  client requests into reader-sized batches within a ``coalesce_ms``
  window — `QueryQueue`, unit-tested in isolation), per-reader health
  (a failed dispatch marks the reader down, requeues its batch for the
  others, and retries the connection in the background) and staleness
  accounting per answer (published head version − answered version).

Every role is launched from ONE serialized `ServeSpec`
(`launch/config.py`) plus its role-local flags (port, reader id):

    python -m repro.launch.replica --role serve --readers 2 --verify ...

spawns and supervises the whole topology (the ``serve`` role also
drives an open-loop client stream and, with ``--verify``, checks every
answer against the Dijkstra oracle at the version it was served —
exactly the `ServeLoop --verify` contract, across process boundaries).

Staleness ≤ 1 survives the boundary because (a) a reader only flips to
a version whose publish record — and the step it names — are fsync'd,
(b) the updater's publish barrier keeps any acked reader within one
published version of head, and (c) answers carry the version they were
computed at, so the router can always account the lag it served.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import signal
import socket
import struct
import subprocess
import sys
import threading
import time
from collections import deque

import numpy as np

# ---------------------------------------------------------------------------
# Wire protocol: tiny length-framed messages over localhost TCP
# ---------------------------------------------------------------------------

MSG_QUERY = 1    # -> router/reader:  u32 m | i32 qs[m] | i32 qt[m]
MSG_ANSWER = 2   # <- router/reader:  i64 version | i64 head | u32 m | i32 d[m]
MSG_REJECT = 3   # <- router:         utf-8 reason (admission control)
MSG_PING = 4     # -> reader:         empty
MSG_PONG = 5     # <- reader:         i64 version
MSG_STATS = 6    # -> router: empty   <- router: utf-8 JSON
MSG_STOP = 7     # -> router/reader:  empty; peer exits cleanly

_HDR = struct.Struct("<BI")


def send_msg(sock: socket.socket, kind: int, payload: bytes = b"") -> None:
    sock.sendall(_HDR.pack(kind, len(payload)) + payload)


def recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("peer closed")
        buf += chunk
    return bytes(buf)


def recv_msg(sock: socket.socket) -> tuple[int, bytes]:
    kind, ln = _HDR.unpack(recv_exact(sock, _HDR.size))
    return kind, (recv_exact(sock, ln) if ln else b"")


def pack_query(qs: np.ndarray, qt: np.ndarray) -> bytes:
    qs = np.asarray(qs, np.int32).ravel()
    qt = np.asarray(qt, np.int32).ravel()
    return struct.pack("<I", qs.size) + qs.tobytes() + qt.tobytes()


def unpack_query(payload: bytes) -> tuple[np.ndarray, np.ndarray]:
    (m,) = struct.unpack_from("<I", payload)
    qs = np.frombuffer(payload, np.int32, m, 4)
    qt = np.frombuffer(payload, np.int32, m, 4 + 4 * m)
    return qs, qt


def pack_answer(version: int, head: int, d: np.ndarray) -> bytes:
    d = np.asarray(d, np.int32).ravel()
    return struct.pack("<qqI", version, head, d.size) + d.tobytes()


def unpack_answer(payload: bytes) -> tuple[int, int, np.ndarray]:
    version, head, m = struct.unpack_from("<qqI", payload)
    return version, head, np.frombuffer(payload, np.int32, m, 20)


# ---------------------------------------------------------------------------
# QueryQueue: admission control + microbatch coalescing (router core)
# ---------------------------------------------------------------------------

class QueryQueue:
    """Bounded FIFO of pending query entries with microbatch coalescing.

    The router's two policies live here, socket-free and unit-testable
    (tests/test_replica.py):

    * **admission control** — `offer` counts *queries* (not requests);
      beyond `max_pending` it refuses, and the caller rejects the client
      immediately instead of letting the queue (and tail latency) grow
      without bound.
    * **coalescing** — `take` blocks for the first entry, then keeps
      gathering whole entries until the batch holds `microbatch` queries
      or `coalesce_s` has elapsed since the batch opened. Entries are
      never split, so each client request is answered at one version.
    """

    def __init__(self, max_pending: int, microbatch: int,
                 coalesce_s: float):
        self.max_pending = max_pending
        self.microbatch = microbatch
        self.coalesce_s = coalesce_s
        self._cv = threading.Condition()
        self._items: deque = deque()
        self._pending = 0          # queries currently queued
        self.rejected = 0          # admission-control refusals (queries)

    @property
    def pending(self) -> int:
        return self._pending

    def offer(self, entry, m: int, front: bool = False) -> bool:
        """Enqueue `entry` carrying `m` queries; False = admission refusal.

        `front=True` requeues a batch reclaimed from a failed reader at
        the head (those queries already waited their turn) and is exempt
        from admission — dropping them would turn a reader crash into
        client-visible rejections.
        """
        with self._cv:
            if not front and self._pending + m > self.max_pending:
                self.rejected += m
                return False
            (self._items.appendleft if front
             else self._items.append)((entry, m))
            self._pending += m
            self._cv.notify()
            return True

    def take(self, timeout: float = 0.1) -> list:
        """One coalesced batch (possibly empty after `timeout`)."""
        with self._cv:
            if not self._items and not self._cv.wait_for(
                    lambda: bool(self._items), timeout):
                return []
            batch, got = [], 0
            opened = time.monotonic()
            while True:
                while self._items and (
                        not batch
                        or got + self._items[0][1] <= self.microbatch):
                    entry, m = self._items.popleft()
                    self._pending -= m
                    batch.append(entry)
                    got += m
                if got >= self.microbatch:
                    break
                remaining = self.coalesce_s - (time.monotonic() - opened)
                if remaining <= 0:
                    break
                self._cv.wait(remaining)
                if not self._items:
                    break
            return batch


# ---------------------------------------------------------------------------
# Publish/ack records (the updater<->reader side channel, via the FS)
# ---------------------------------------------------------------------------

def _ack_dir(publish_dir: str) -> str:
    return os.path.join(publish_dir, "acks")


def write_ack(publish_dir: str, reader_id: int, version: int) -> None:
    from repro.checkpoint import manager as ckpt
    os.makedirs(_ack_dir(publish_dir), exist_ok=True)
    ckpt.write_json_atomic(
        os.path.join(_ack_dir(publish_dir), f"reader_{reader_id}.json"),
        {"version": int(version), "pid": os.getpid()})


def read_acks(publish_dir: str) -> dict[int, dict]:
    from repro.checkpoint import manager as ckpt
    d = _ack_dir(publish_dir)
    if not os.path.isdir(d):
        return {}
    out = {}
    for name in os.listdir(d):
        if name.startswith("reader_") and name.endswith(".json"):
            rec = ckpt.read_json(os.path.join(d, name))
            if rec is not None:
                out[int(name[len("reader_"):-len(".json")])] = rec
    return out


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except (ProcessLookupError, PermissionError):
        return False
    return True


def wait_for_acks(publish_dir: str, version: int, timeout_s: float,
                  log=print) -> bool:
    """The publish barrier: block until every *live* acked reader is at
    >= `version` (True), or `timeout_s` passed (False — the updater
    proceeds rather than wedging the write path on a stuck reader; the
    event is logged and the stuck reader re-syncs from CURRENT when it
    recovers)."""
    deadline = time.monotonic() + timeout_s
    while True:
        behind = [rid for rid, rec in read_acks(publish_dir).items()
                  if rec["version"] < version and _pid_alive(rec["pid"])]
        if not behind:
            return True
        if time.monotonic() >= deadline:
            log(f"publish barrier timeout: readers {behind} below "
                f"v{version} after {timeout_s:.0f}s; publishing anyway")
            return False
        time.sleep(0.005)


# ---------------------------------------------------------------------------
# Updater role
# ---------------------------------------------------------------------------

def updater_main(spec, publish_dir: str) -> None:
    """Run the batch-update loop; publish every version durably.

    Exactly `ServeLoop` with the query stream off — the same growth,
    autotune, mesh, and pipeline semantics — plus the publish protocol
    on the hooks: the initial snapshot and every commit are saved
    (fsync + atomic rename), barrier-gated on reader acks of the
    previous version, and then pointed to by ``CURRENT``.
    """
    from repro.checkpoint import manager as ckpt
    from repro.core.snapshot import save_snapshot
    from repro.launch.serve import ServeLoop

    cfg = spec.to_serve_config(
        queries=0, ckpt_dir=publish_dir,
        autotune=spec.engine.autotune or spec.engine.tune_table is not None)
    loop = ServeLoop(cfg)
    keep = spec.checkpoint.keep

    def edge_state() -> dict:
        rows = np.asarray(
            [(u, v, loop._edge_w.get((u, v), 1))
             for u, v in loop._edge_list], np.int32).reshape(-1, 3)
        return {"edge_list": rows, "base_n": np.int64(cfg.n)}

    def on_start(snap0) -> None:
        save_snapshot(publish_dir, snap0, extra=edge_state())
        ckpt.publish(publish_dir, snap0.version)
        loop._log(f"updater: published v{snap0.version}")

    def on_commit(tick: int, snap) -> None:
        # run() already checkpointed `snap` (fsync'd rename); gate the
        # pointer flip on the acks of the *previous* version so no
        # reader observes a head two published versions ahead.
        wait_for_acks(publish_dir, snap.version - 1,
                      spec.topology.barrier_timeout_s, log=loop._log)
        ckpt.publish(publish_dir, snap.version)
        if keep is not None:
            ckpt.prune(publish_dir, keep=keep)
        loop._log(f"updater: published v{snap.version}")

    loop.on_start = on_start
    loop.on_commit = on_commit
    loop.run()


# ---------------------------------------------------------------------------
# Reader role
# ---------------------------------------------------------------------------

class _ReaderServer:
    """One reader replica: maps the published snapshot, answers queries.

    Single process, thread-per-connection (the router holds one);
    a poller thread watches ``CURRENT`` and swaps the local snapshot —
    the flip is one attribute store, atomic under the GIL, and is acked
    only *after* the new version is mapped and query-ready (warmed), so
    the updater's barrier never counts a reader that could still answer
    at the old version without knowing about the new one.
    """

    def __init__(self, spec, publish_dir: str, port: int, reader_id: int):
        self.spec = spec
        self.publish_dir = publish_dir
        self.port = port
        self.reader_id = reader_id
        self.running = True
        self._snap = None
        self._mesh = None
        self._engine = None

    # -- snapshot mapping ---------------------------------------------------

    def _build_engine(self):
        from repro.core.engine import RelaxEngine
        from repro.core.shard import validate_landmark_sharding
        from repro.launch.mesh import make_host_mesh
        e = self.spec.engine
        if e.mesh == "host":
            self._mesh = make_host_mesh(model=e.shards)
            validate_landmark_sharding(self._mesh,
                                       self.spec.graph.landmarks)
        # Same engine surface as the updater's ServeLoop — autotuned
        # pallas readers serve the tuner's winner (off-TPU that is the
        # compiled sorted segment-min twin, not the interpret-mode
        # kernel), measured once per snapshot shape at first prepare.
        self._engine = RelaxEngine(backend=e.backend, block_v=e.block_v,
                                   shards=e.tile_shards, block_e=e.block_e,
                                   autotune=(e.autotune
                                             or e.tune_table is not None),
                                   tune_table=e.tune_table)

    def _buckets(self) -> list[int]:
        """Padding widths the query path is compiled at. Coalesced
        dispatches are padded up to the nearest bucket, not always to
        the full microbatch — a 2-query dispatch at low load must not
        pay a 32-wide sweep (that flat compute floor is what caps
        sustained qps on core-constrained hosts)."""
        mb = self.spec.stream.microbatch
        return sorted({1, min(8, mb), mb})

    def _map_version(self, version: int) -> None:
        """Map step `version` (mmap'd leaves), prepare, warm, flip, ack."""
        import jax.numpy as jnp
        from repro.core.snapshot import restore_snapshot

        snap = restore_snapshot(self.publish_dir, step=version, mmap=True)
        snap = dataclasses.replace(
            snap, plan=self._engine.prepare(snap.graph))
        # Warm the query path at each serving bucket so no routed
        # dispatch after a flip pays the trace (compiles are shared
        # across versions — shapes don't change — so only the first
        # map traces; later maps just execute once per bucket).
        for w in self._buckets():
            z = jnp.zeros((w,), jnp.int32)
            self._answer_snap(snap, z, z)
        self._snap = snap
        write_ack(self.publish_dir, self.reader_id, version)

    def _answer_snap(self, snap, qs, qt) -> np.ndarray:
        import jax
        from repro.core.query import batched_query
        from repro.core.shard import shard_batched_query
        if self._mesh is None:
            d = batched_query(snap.graph, snap.labelling, qs, qt,
                              use_kernel=self.spec.engine.use_minplus_kernel,
                              plan=snap.plan)
        else:
            d = shard_batched_query(
                self._mesh, snap.graph, snap.labelling, qs, qt,
                use_kernel=self.spec.engine.use_minplus_kernel,
                plan=snap.plan)
        jax.block_until_ready(d)
        return np.asarray(d)

    def answer(self, qs: np.ndarray, qt: np.ndarray
               ) -> tuple[np.ndarray, int]:
        import jax.numpy as jnp
        snap = self._snap  # one load: consistent snapshot for the batch
        m = qs.shape[0]
        # Pad to the nearest warmed bucket (an oversized ad-hoc batch
        # runs at its own width and eats the trace).
        width = next((w for w in self._buckets() if w >= m), m)
        idx = np.concatenate([np.arange(m, dtype=np.int64),
                              np.zeros(width - m, np.int64)])
        d = self._answer_snap(snap, jnp.asarray(qs[idx]),
                              jnp.asarray(qt[idx]))
        return d[:m], snap.version

    # -- polling + serving --------------------------------------------------

    def _poll_loop(self) -> None:
        from repro.checkpoint import manager as ckpt
        poll_s = self.spec.topology.poll_ms / 1e3
        while self.running:
            try:
                cur = ckpt.current_step(self.publish_dir)
                if cur is not None and (self._snap is None
                                        or cur != self._snap.version):
                    self._map_version(cur)
            except FileNotFoundError:
                pass  # pointer mid-prune race; next poll settles it
            time.sleep(poll_s)

    def _client_loop(self, conn: socket.socket) -> None:
        try:
            with conn:
                while self.running:
                    kind, payload = recv_msg(conn)
                    if kind == MSG_QUERY:
                        qs, qt = unpack_query(payload)
                        d, version = self.answer(qs, qt)
                        send_msg(conn, MSG_ANSWER,
                                 pack_answer(version, version, d))
                    elif kind == MSG_PING:
                        v = self._snap.version if self._snap else -1
                        send_msg(conn, MSG_PONG, struct.pack("<q", v))
                    elif kind == MSG_STOP:
                        self.running = False
                        return
        except (ConnectionError, OSError):
            return

    def serve_forever(self) -> None:
        from repro.checkpoint import manager as ckpt
        host = self.spec.topology.host
        # Map the first published version before accepting queries.
        deadline = time.monotonic() + 120.0
        self._build_engine()
        while True:
            cur = ckpt.current_step(self.publish_dir)
            if cur is not None:
                self._map_version(cur)
                break
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"reader {self.reader_id}: no CURRENT under "
                    f"{self.publish_dir} after 120s")
            time.sleep(0.05)
        threading.Thread(target=self._poll_loop, daemon=True).start()

        srv = socket.create_server((host, self.port))
        srv.settimeout(0.25)
        print(f"reader {self.reader_id}: serving v{self._snap.version} "
              f"on {host}:{self.port}", flush=True)
        while self.running:
            try:
                conn, _ = srv.accept()
            except socket.timeout:
                continue
            threading.Thread(target=self._client_loop, args=(conn,),
                             daemon=True).start()
        srv.close()


def reader_main(spec, publish_dir: str, port: int, reader_id: int) -> None:
    _ReaderServer(spec, publish_dir, port, reader_id).serve_forever()


# ---------------------------------------------------------------------------
# Router role
# ---------------------------------------------------------------------------

class _Entry:
    """One admitted client request awaiting its coalesced dispatch."""
    __slots__ = ("sock", "lock", "qs", "qt", "t_arrival")

    def __init__(self, sock, lock, qs, qt):
        self.sock, self.lock = sock, lock
        self.qs, self.qt = qs, qt
        self.t_arrival = time.monotonic()


class Router:
    """Admission control + coalescing + reader health, one thread per
    reader endpoint (each pulls a batch when its reader is free — load
    balancing falls out of the pull loop, no placement policy needed)."""

    def __init__(self, spec, publish_dir: str, port: int,
                 reader_addrs: list[tuple[str, int]]):
        topo = spec.topology
        self.spec = spec
        self.publish_dir = publish_dir
        self.port = port
        self.reader_addrs = reader_addrs
        self.queue = QueryQueue(topo.max_queue, spec.stream.microbatch,
                                topo.coalesce_ms / 1e3)
        self.running = True
        self._head = -1
        self._head_at = 0.0
        self._stats_lock = threading.Lock()
        # Query-denominated counters. Admission refusals are owned by
        # the queue (`QueryQueue.rejected`) — the stats doc reads them
        # from there so the count has exactly one owner; `oversized`
        # covers requests refused before they ever reach the queue.
        self.stats = {
            "answered": 0, "oversized": 0, "requeued": 0,
            "per_reader": {i: 0 for i in range(len(reader_addrs))},
            "reader_errors": {i: 0 for i in range(len(reader_addrs))},
            "staleness": {},  # lag -> answer count
        }

    # -- head-version cache (staleness accounting) --------------------------

    def head(self) -> int:
        now = time.monotonic()
        if now - self._head_at > self.spec.topology.poll_ms / 1e3:
            from repro.checkpoint import manager as ckpt
            cur = ckpt.current_step(self.publish_dir)
            if cur is not None:
                self._head = cur
            self._head_at = now
        return self._head

    # -- client side --------------------------------------------------------

    def _client_loop(self, conn: socket.socket) -> None:
        lock = threading.Lock()
        try:
            with conn:
                while self.running:
                    kind, payload = recv_msg(conn)
                    if kind == MSG_QUERY:
                        qs, qt = unpack_query(payload)
                        if qs.size > self.spec.stream.microbatch:
                            with self._stats_lock:
                                self.stats["oversized"] += int(qs.size)
                            with lock:
                                send_msg(conn, MSG_REJECT,
                                         b"request larger than microbatch")
                            continue
                        entry = _Entry(conn, lock, qs, qt)
                        if not self.queue.offer(entry, qs.size):
                            # `offer` already counted the refusal in
                            # queue.rejected; counting it again here
                            # double-reported every admission reject.
                            with lock:
                                send_msg(conn, MSG_REJECT, b"overloaded")
                    elif kind == MSG_STATS:
                        with self._stats_lock:
                            doc = json.dumps(
                                {**self.stats,
                                 "rejected": self.queue.rejected,
                                 "pending": self.queue.pending,
                                 "head": self.head()})
                        send_msg(conn, MSG_STATS, doc.encode())
                    elif kind == MSG_STOP:
                        self.running = False
                        return
        except (ConnectionError, OSError):
            return

    # -- reader side --------------------------------------------------------

    def _dispatch_loop(self, ridx: int) -> None:
        addr = self.reader_addrs[ridx]
        sock = None
        backoff = 0.05
        while self.running:
            if sock is None:
                try:
                    sock = socket.create_connection(addr, timeout=5.0)
                    sock.settimeout(30.0)
                    backoff = 0.05
                except OSError:
                    time.sleep(backoff)
                    backoff = min(backoff * 2, 1.0)
                    continue
            batch = self.queue.take(timeout=0.05)
            if not batch:
                continue
            qs = np.concatenate([e.qs for e in batch])
            qt = np.concatenate([e.qt for e in batch])
            try:
                send_msg(sock, MSG_QUERY, pack_query(qs, qt))
                kind, payload = recv_msg(sock)
                if kind != MSG_ANSWER:
                    raise ConnectionError(f"unexpected reply kind {kind}")
            except (ConnectionError, OSError, socket.timeout):
                # Reader down: reclaim the batch for the healthy readers
                # (reads are idempotent — retry is safe), drop the
                # connection, and go back to reconnecting.
                try:
                    if sock is not None:
                        sock.close()
                finally:
                    sock = None
                with self._stats_lock:
                    self.stats["reader_errors"][ridx] += 1
                    # Queries, not entries — every other counter in this
                    # dict is query-denominated.
                    self.stats["requeued"] += int(qs.size)
                for e in reversed(batch):
                    self.queue.offer(e, e.qs.size, front=True)
                continue
            version, _, d = unpack_answer(payload)
            head = max(self.head(), version)
            off = 0
            for e in batch:
                m = e.qs.size
                try:
                    with e.lock:
                        send_msg(e.sock, MSG_ANSWER,
                                 pack_answer(version, head,
                                             d[off:off + m]))
                except (ConnectionError, OSError):
                    pass  # client went away; the answer dies with it
                off += m
            with self._stats_lock:
                self.stats["answered"] += int(qs.size)
                self.stats["per_reader"][ridx] += int(qs.size)
                lag = str(head - version)
                self.stats["staleness"][lag] = \
                    self.stats["staleness"].get(lag, 0) + int(qs.size)

    def serve_forever(self) -> None:
        for ridx in range(len(self.reader_addrs)):
            threading.Thread(target=self._dispatch_loop, args=(ridx,),
                             daemon=True).start()
        srv = socket.create_server((self.spec.topology.host, self.port))
        srv.settimeout(0.25)
        print(f"router: {len(self.reader_addrs)} readers on "
              f"{self.spec.topology.host}:{self.port}", flush=True)
        while self.running:
            try:
                conn, _ = srv.accept()
            except socket.timeout:
                continue
            threading.Thread(target=self._client_loop, args=(conn,),
                             daemon=True).start()
        srv.close()


def router_main(spec, publish_dir: str, port: int,
                reader_addrs: list[tuple[str, int]]) -> None:
    Router(spec, publish_dir, port, reader_addrs).serve_forever()


# ---------------------------------------------------------------------------
# Client
# ---------------------------------------------------------------------------

class RejectedError(RuntimeError):
    """The router refused the request (admission control / overload)."""


class RouterClient:
    """Synchronous client of one router connection (thread-unsafe; use
    one per worker thread)."""

    def __init__(self, host: str, port: int, timeout: float = 30.0):
        self.sock = socket.create_connection((host, port), timeout=timeout)
        self.sock.settimeout(timeout)

    def query(self, qs, qt) -> tuple[np.ndarray, int, int]:
        """Answer a batch → (distances, version, head). Raises
        `RejectedError` when admission control refuses it."""
        send_msg(self.sock, MSG_QUERY, pack_query(qs, qt))
        kind, payload = recv_msg(self.sock)
        if kind == MSG_REJECT:
            raise RejectedError(payload.decode())
        version, head, d = unpack_answer(payload)
        return d, version, head

    def stats(self) -> dict:
        send_msg(self.sock, MSG_STATS)
        kind, payload = recv_msg(self.sock)
        return json.loads(payload.decode())

    def stop_peer(self) -> None:
        try:
            send_msg(self.sock, MSG_STOP)
        except OSError:
            pass

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# Orchestrator: spawn + supervise the topology, drive the client stream
# ---------------------------------------------------------------------------

def free_port(host: str = "127.0.0.1") -> int:
    with socket.socket() as s:
        s.bind((host, 0))
        return s.getsockname()[1]


def _role_env() -> dict:
    src = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env


@dataclasses.dataclass
class AnswerRecord:
    """One answered client query, with its serving version + staleness."""
    qs: int
    qt: int
    answer: int
    version: int
    staleness: int
    latency_s: float


@dataclasses.dataclass
class ReplicaReport:
    """What one topology run produced (benches + tests consume this)."""
    answers: list[AnswerRecord]
    rejected: int
    router_stats: dict
    reader_restarts: int

    def latency_percentiles(self) -> dict[str, float]:
        lat = np.asarray([a.latency_s for a in self.answers])
        if lat.size == 0:
            return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {p: float(np.percentile(lat, q))
                for p, q in (("p50", 50), ("p95", 95), ("p99", 99))}

    def max_staleness(self) -> int:
        return max((a.staleness for a in self.answers), default=0)


class ReplicaTopology:
    """Spawn and supervise 1 updater + N readers + 1 router.

    `watch()` is the crash detector: a reader process that died is
    relaunched (same id, same port) when the topology was configured
    with `restart`; the new process re-maps from ``CURRENT`` and the
    router's dispatch loop reconnects on its own. The updater is never
    restarted implicitly — it is the single writer, and a half-done
    update must resume through ``--resume`` semantics deliberately.
    """

    def __init__(self, spec, publish_dir: str):
        self.spec = spec
        self.publish_dir = publish_dir
        self.config_path = os.path.join(publish_dir, "config.json")
        topo = spec.topology
        self.router_port = topo.router_port or free_port(topo.host)
        self.reader_ports = [
            (topo.reader_port0 + k) if topo.reader_port0 else
            free_port(topo.host) for k in range(topo.readers)]
        self.updater: subprocess.Popen | None = None
        self.router: subprocess.Popen | None = None
        self.readers: list[subprocess.Popen | None] = \
            [None] * topo.readers
        self.reader_restarts = 0

    def _spawn(self, role: str, *extra: str) -> subprocess.Popen:
        cmd = [sys.executable, "-m", "repro.launch.replica",
               "--role", role, "--config", self.config_path,
               "--publish-dir", self.publish_dir, *extra]
        # quiet topologies (benchmarks) keep role chatter off the CSV
        # stream; stderr stays inherited so failures surface.
        out = subprocess.DEVNULL if self.spec.stream.quiet else None
        return subprocess.Popen(cmd, env=_role_env(), stdout=out)

    def start_reader(self, k: int) -> None:
        self.readers[k] = self._spawn(
            "reader", "--reader-id", str(k),
            "--port", str(self.reader_ports[k]))

    def start(self, timeout_s: float = 180.0) -> None:
        os.makedirs(self.publish_dir, exist_ok=True)
        self.spec.save_json(self.config_path)
        self.updater = self._spawn("updater")
        for k in range(self.spec.topology.readers):
            self.start_reader(k)
        addrs = ",".join(f"{self.spec.topology.host}:{p}"
                         for p in self.reader_ports)
        self.router = self._spawn("router", "--port",
                                  str(self.router_port),
                                  "--reader-addrs", addrs)
        # Ready when the router accepts and a reader answers a probe
        # end-to-end (implies CURRENT exists and a snapshot is mapped).
        deadline = time.monotonic() + timeout_s
        while True:
            if self.updater.poll() not in (None, 0):
                raise RuntimeError(
                    f"updater exited rc={self.updater.returncode} "
                    f"during startup")
            try:
                c = RouterClient(self.spec.topology.host,
                                 self.router_port, timeout=5.0)
                d, _, _ = c.query(np.zeros(1, np.int32),
                                  np.zeros(1, np.int32))
                c.close()
                if d.shape == (1,):
                    return
            except (OSError, RejectedError):
                pass
            if time.monotonic() > deadline:
                raise TimeoutError("replica topology not ready in "
                                   f"{timeout_s:.0f}s")
            time.sleep(0.2)

    def client(self, timeout: float = 30.0) -> RouterClient:
        return RouterClient(self.spec.topology.host, self.router_port,
                            timeout=timeout)

    def kill_reader(self, k: int) -> None:
        p = self.readers[k]
        if p is not None and p.poll() is None:
            p.kill()
            p.wait()

    def watch(self) -> None:
        """Crash detection: restart dead readers (when configured)."""
        for k, p in enumerate(self.readers):
            if p is not None and p.poll() is not None \
                    and self.spec.topology.restart:
                self.reader_restarts += 1
                self.start_reader(k)

    def updater_running(self) -> bool:
        return self.updater is not None and self.updater.poll() is None

    def updater_ok(self) -> bool:
        rc = None if self.updater is None else self.updater.poll()
        return rc in (None, 0)

    def stop(self) -> None:
        for p in [self.router, *self.readers, self.updater]:
            if p is not None and p.poll() is None:
                p.terminate()
        for p in [self.router, *self.readers, self.updater]:
            if p is not None:
                try:
                    p.wait(timeout=10)
                except subprocess.TimeoutExpired:
                    p.kill()
                    p.wait()


def stream_queries(spec, topology: ReplicaTopology, total: int,
                   qps: float, workers: int = 4,
                   on_tick=None) -> ReplicaReport:
    """Drive an open-loop Poisson client stream through the router.

    `workers` concurrent connections pull from one arrival schedule —
    each query is sent as its own request (m=1), so the router's
    coalescing (not the client) is what builds reader microbatches.
    Latency is arrival → answered, the `ServeLoop` convention.
    """
    n = spec.graph.realized_n()
    arr = np.random.default_rng((spec.stream.seed, 911))
    offsets = np.cumsum(arr.exponential(1.0 / qps, size=total))
    qrng = np.random.default_rng((spec.stream.seed, 912))
    qs = qrng.integers(0, n, total).astype(np.int32)
    qt = qrng.integers(0, n, total).astype(np.int32)

    answers: list[AnswerRecord] = []
    rejected = [0]
    next_idx = [0]
    lock = threading.Lock()
    t0 = time.monotonic()

    def worker() -> None:
        client = topology.client()
        try:
            while True:
                with lock:
                    i = next_idx[0]
                    if i >= total:
                        return
                    next_idx[0] += 1
                due = t0 + offsets[i]
                wait = due - time.monotonic()
                if wait > 0:
                    time.sleep(wait)
                try:
                    d, version, head = client.query(qs[i:i + 1],
                                                    qt[i:i + 1])
                except RejectedError:
                    with lock:
                        rejected[0] += 1
                    continue
                rec = AnswerRecord(
                    qs=int(qs[i]), qt=int(qt[i]), answer=int(d[0]),
                    version=version, staleness=head - version,
                    latency_s=time.monotonic() - due)
                with lock:
                    answers.append(rec)
        finally:
            client.close()

    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(workers)]
    for t in threads:
        t.start()
    while any(t.is_alive() for t in threads):
        topology.watch()
        if on_tick is not None:
            on_tick()
        time.sleep(0.1)
    for t in threads:
        t.join()

    stats = {}
    try:
        c = topology.client(timeout=5.0)
        stats = c.stats()
        c.close()
    except OSError:
        pass
    return ReplicaReport(answers=answers, rejected=rejected[0],
                         router_stats=stats,
                         reader_restarts=topology.reader_restarts)


def verify_answers(publish_dir: str, answers: list[AnswerRecord],
                   limit: int | None = None) -> int:
    """Check answers against the Dijkstra oracle *at the version each
    was served* — the `ServeLoop --verify` contract across the process
    boundary. Returns the mismatch count."""
    from repro.core import ref
    from repro.core.snapshot import restore_snapshot
    from repro.graphs.coo import to_numpy_wadj

    wadj_at: dict[int, dict] = {}
    wrong = 0
    for rec in answers[:limit]:
        if rec.version not in wadj_at:
            snap = restore_snapshot(publish_dir, step=rec.version,
                                    mmap=True)
            wadj_at[rec.version] = to_numpy_wadj(snap.graph)
        adj = wadj_at[rec.version]
        got = float(rec.answer)
        want = ref.pair_distance_w(adj, len(adj), rec.qs, rec.qt)
        want = got if (want == ref.INF and got >= 1e8) else want
        if rec.qs == rec.qt:
            want = 0
        wrong += int(got != want)
    return wrong


def serve_main(spec, publish_dir: str, verify_limit: int | None) -> None:
    """The ``serve`` role: run the whole topology + a client stream."""
    topo = ReplicaTopology(spec, publish_dir)
    total = spec.stream.queries * spec.stream.batches
    try:
        topo.start()
        report = stream_queries(spec, topo, total, spec.stream.qps)
        pct = report.latency_percentiles()
        print(f"replica serve: {len(report.answers)}/{total} answered "
              f"({report.rejected} rejected, "
              f"{report.reader_restarts} reader restarts) | "
              f"p50 {pct['p50'] * 1e3:.1f}ms p95 {pct['p95'] * 1e3:.1f}ms "
              f"p99 {pct['p99'] * 1e3:.1f}ms | "
              f"max staleness {report.max_staleness()} | "
              f"router {report.router_stats}", flush=True)
        if not topo.updater_ok():
            raise SystemExit(
                f"updater failed rc={topo.updater.returncode}")
        if report.max_staleness() > 1:
            raise SystemExit(
                f"staleness contract violated: max "
                f"{report.max_staleness()} > 1")
        if spec.stream.verify:
            wrong = verify_answers(publish_dir, report.answers,
                                   limit=verify_limit)
            checked = len(report.answers[:verify_limit])
            print(f"verify: {wrong}/{checked} mismatches", flush=True)
            if wrong:
                raise SystemExit(f"verify FAILED: {wrong} mismatches")
    finally:
        topo.stop()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser(
        description="replica serve tier: updater / reader / router roles, "
                    "all launched from one serialized ServeSpec")
    ap.add_argument("--role", required=True,
                    choices=("updater", "reader", "router", "serve"))
    ap.add_argument("--config", default=None,
                    help="serialized ServeSpec JSON (required for "
                         "updater/reader/router; the serve role also "
                         "accepts flat flags)")
    ap.add_argument("--publish-dir", required=True,
                    help="the publish directory: step_<v> checkpoints + "
                         "the CURRENT pointer + reader acks")
    ap.add_argument("--reader-id", type=int, default=0)
    ap.add_argument("--port", type=int, default=0,
                    help="bind port of this reader/router")
    ap.add_argument("--reader-addrs", default="",
                    help="router role: comma-separated host:port of the "
                         "readers")
    ap.add_argument("--verify-limit", type=int, default=None,
                    help="serve role: oracle-check at most this many "
                         "answers (default: all)")
    # The serve role accepts the full flat-flag surface too, so CI can
    # launch a topology without materializing a JSON first.
    from repro.launch.config import ServeSpec, spec_from_cli
    ServeSpec.add_args(ap)
    args = ap.parse_args()

    if args.config:
        spec = ServeSpec.load_json(args.config)
    elif args.role == "serve":
        spec = spec_from_cli(args, ap)
    else:
        ap.error(f"--config is required for the {args.role} role (every "
                 "process of one deployment shares one serialized spec)")

    signal.signal(signal.SIGTERM, lambda *a: sys.exit(0))
    if args.role == "updater":
        updater_main(spec, args.publish_dir)
    elif args.role == "reader":
        reader_main(spec, args.publish_dir, args.port, args.reader_id)
    elif args.role == "router":
        addrs = []
        for part in args.reader_addrs.split(","):
            host, _, port = part.rpartition(":")
            addrs.append((host, int(port)))
        router_main(spec, args.publish_dir, args.port, addrs)
    else:
        serve_main(spec, args.publish_dir, args.verify_limit)


if __name__ == "__main__":
    main()
