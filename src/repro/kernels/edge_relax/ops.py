"""Jit'd wrappers: tiled Pallas edge relaxation with jnp fallback.

`BlockedGraph` carries the one-off destination-block tiling, organized as
`shards` contiguous block_v-aligned vertex shards (leading [S] axis on every
tile array; S=1 is the classic unsharded tiling). The tiling is purely
topological (src / local-dst / original-slot permutation): per-sweep edge
validity — which churns with every batch update and with the repair
boundary/interior masks — is re-tiled on device with a single gather
through `perm_t`, so re-tiling on host is needed only when topology slots
change (insertions rewrite src/dst), not per wave and not per deletion.
Because no destination block straddles a shard boundary, sweep results are
bit-identical for every S — the shard axis only shapes the launch grid
(and, under a mesh, which slice a device owns). `core/engine.py` owns the
cache; this module owns the kernel launch.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.edge_relax import kernel, ref


@partial(jax.tree_util.register_dataclass,
         data_fields=("src_t", "dstloc_t", "valid_t", "perm_t", "slot_t"),
         meta_fields=("n", "block_v"))
@dataclasses.dataclass(frozen=True)
class BlockedGraph:
    src_t: jax.Array     # int32[S, NB, BE] source vertex per tile slot
    dstloc_t: jax.Array  # int32[S, NB, BE] destination local to the block
    valid_t: jax.Array   # int32[S, NB, BE] validity baked at prepare time
    perm_t: jax.Array    # int32[S, NB, BE] original edge-slot index
    slot_t: jax.Array    # int32[S, NB, BE] 1 on real slots, 0 on padding
    n: int
    block_v: int

    @property
    def shards(self) -> int:
        """Vertex-shard count S of the tiling (leading tile axis)."""
        return self.src_t.shape[0]

    def tile_mask(self, edge_mask: jax.Array) -> jax.Array:
        """Re-tile a per-edge mask (original slot order) on device."""
        return jnp.where(self.slot_t != 0,
                         edge_mask[self.perm_t], False).astype(jnp.int32)

    def tile_plane(self, plane: jax.Array, fill) -> jax.Array:
        """Pad + reshape a per-vertex plane [V] to dst tiles [S, NB, BV]."""
        s, nb, _ = self.src_t.shape
        npad = s * nb * self.block_v
        padded = jnp.full((npad,), fill, plane.dtype).at[:self.n].set(plane)
        return padded.reshape(s, nb, self.block_v)


def prepare(src, dst, valid, n: int, block_v: int = 512,
            shards: int = 1) -> BlockedGraph:
    """Tile every edge slot; bake `valid` into valid_t (legacy entry)."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    valid = np.asarray(valid, bool)
    src_t, dstloc_t, perm_t, slot_t, bv = kernel.block_edges_topology(
        src, dst, np.ones(len(src), bool), n, block_v)
    valid_t = np.where(slot_t != 0, valid[perm_t].astype(np.int32), 0)
    src_t, dstloc_t, valid_t, perm_t, slot_t = kernel.shard_tiling(
        shards, src_t, dstloc_t, valid_t.astype(np.int32), perm_t, slot_t)
    return BlockedGraph(jnp.asarray(src_t), jnp.asarray(dstloc_t),
                        jnp.asarray(valid_t), jnp.asarray(perm_t),
                        jnp.asarray(slot_t), n, bv)


def prepare_topology(src, dst, keep, n: int, block_v: int = 512,
                     shards: int = 1) -> BlockedGraph:
    """Tile only the `keep` slots (host sync; amortized by core/engine.py).

    `keep` should be the currently-occupied slots: future deletions only
    flip validity (handled per sweep via `tile_mask`), while insertions
    rewrite src/dst and therefore force a fresh prepare anyway.

    `shards` splits the destination-block tiling into that many contiguous
    vertex shards (the leading [S] tile axis — see `kernel.shard_tiling`);
    results are bit-identical for every S.

    The returned tiling sets `valid_t` to slot *occupancy*, not edge
    validity — it must only be consumed through `relax_sweep`, which
    re-tiles the caller's current per-edge mask via `perm_t` every wave.
    Feeding it to the legacy `edge_relax` (which trusts `valid_t`) would
    treat edges deleted after prepare time as still present.
    """
    src_t, dstloc_t, perm_t, slot_t, bv = kernel.block_edges_topology(
        np.asarray(src), np.asarray(dst), np.asarray(keep, bool), n, block_v)
    src_t, dstloc_t, perm_t, slot_t = kernel.shard_tiling(
        shards, src_t, dstloc_t, perm_t, slot_t)
    return BlockedGraph(jnp.asarray(src_t), jnp.asarray(dstloc_t),
                        jnp.asarray(slot_t), jnp.asarray(perm_t),
                        jnp.asarray(slot_t), n, bv)


def edge_relax(keys: jax.Array, bg: BlockedGraph, step,
               use_pallas: bool | None = None) -> jax.Array:
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    interpret = jax.default_backend() != "tpu"
    if use_pallas or interpret is False:
        return kernel.edge_relax_pallas(keys, bg.src_t, bg.dstloc_t,
                                        bg.valid_t, step, bg.n, bg.block_v,
                                        interpret=interpret)
    # jnp fallback on the tiled representation (same math, XLA segment_min).
    s, nb, _ = bg.src_t.shape
    flat_dst = (bg.dstloc_t
                + (jnp.arange(s * nb) * bg.block_v).reshape(s, nb, 1))
    return ref.edge_relax(keys, bg.src_t.reshape(-1), flat_dst.reshape(-1),
                          bg.valid_t.reshape(-1) != 0, step,
                          s * nb * bg.block_v)[:bg.n]


def relax_sweep(keys: jax.Array, bg: BlockedGraph, edge_mask: jax.Array,
                step, inf, clear_bit=0,
                hub: jax.Array | None = None) -> jax.Array:
    """Generalized relaxation sweep on the tiled graph (Pallas path).

    cand[v] = min over edges (u, v) with edge_mask of
        extend(keys[u]) = clear_bit-cleared-if-hub[v] min(keys[u]+step, inf)

    `edge_mask` is in original edge-slot order (length = edge capacity);
    `hub` is a per-vertex bool plane [V] (or None for plain relaxation).
    Runs interpret-mode Pallas off-TPU so parity tests exercise the same
    kernel that runs compiled on TPU.
    """
    mask_t = bg.tile_mask(edge_mask)
    if hub is None:
        s, nb, _ = bg.src_t.shape
        hub_t = jnp.zeros((s, nb, bg.block_v), jnp.int32)
    else:
        hub_t = bg.tile_plane(hub.astype(jnp.int32), 0)
    interpret = jax.default_backend() != "tpu"
    return kernel.relax_sweep_pallas(keys, hub_t, bg.src_t, bg.dstloc_t,
                                     mask_t, step, inf, clear_bit,
                                     bg.n, bg.block_v, interpret=interpret)
