"""batchhl [paper]: the distance-query service itself as a dry-run config.

Production-scale posture: |V| = 2²⁰ vertices, edge capacity 2²³ (16.7M
directed slots), R = 32 landmarks, batches of 1024 updates, query batches
of 1024. Sharding: landmark planes [R, V] split (model → R, data → V);
edges over data; updates replicated (tiny).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import common as cc
from repro.data import synthetic as synth

ARCH_ID = "batchhl"
FAMILY = "batchhl"
# query_1k_repl is the beyond-paper optimized query layout (see §Perf):
# graph + labelling replicated per device (128 MB), queries sharded over
# *all* mesh axes → the BiBFS frontier expansion runs with zero collectives.
SHAPES = ("update_1k", "update_10k", "query_1k", "query_1k_repl",
          "construct")

N_VERTICES = 1 << 20
EDGE_CAP = 1 << 23          # undirected capacity; 2x directed slots
N_LANDMARKS = 32


@dataclasses.dataclass(frozen=True)
class BatchHLConfig:
    name: str = ARCH_ID
    n_vertices: int = N_VERTICES
    edge_cap: int = EDGE_CAP
    n_landmarks: int = N_LANDMARKS
    improved: bool = True        # BHL+ (Algo 3) by default


def model_config() -> BatchHLConfig:
    return BatchHLConfig()


def reduced_config() -> BatchHLConfig:
    return BatchHLConfig(name=ARCH_ID + "-smoke", n_vertices=256,
                         edge_cap=1024, n_landmarks=4)


def _graph_shapes(c: BatchHLConfig):
    e2 = 2 * c.edge_cap
    return {
        "src": jax.ShapeDtypeStruct((e2,), jnp.int32),
        "dst": jax.ShapeDtypeStruct((e2,), jnp.int32),
        "valid": jax.ShapeDtypeStruct((e2,), jnp.bool_),
        "w": jax.ShapeDtypeStruct((e2,), jnp.int32),
    }


def _labelling_shapes(c: BatchHLConfig):
    r, v = c.n_landmarks, c.n_vertices
    return {
        "landmarks": jax.ShapeDtypeStruct((r,), jnp.int32),
        "dist": jax.ShapeDtypeStruct((r, v), jnp.int32),
        "hub": jax.ShapeDtypeStruct((r, v), jnp.bool_),
        "highway": jax.ShapeDtypeStruct((r, r), jnp.int32),
    }


def build_cell(shape_name: str, pod: bool) -> cc.Cell:
    from repro.graphs.coo import Graph, BatchUpdate
    from repro.core.labelling import HighwayLabelling
    from repro.core.batch import batchhl_update
    from repro.core.construct import build_labelling
    from repro.core.query import batched_query

    c = model_config()
    bax = cc.batch_axes(pod)
    gsh = _graph_shapes(c)
    lsh = _labelling_shapes(c)
    g_spec = {"src": P(bax), "dst": P(bax), "valid": P(bax), "w": P(bax)}
    lab_spec = {"landmarks": P(None), "dist": P("model", bax),
                "hub": P("model", bax), "highway": P(None, None)}

    def g_struct(shapes):
        return Graph(src=shapes["src"], dst=shapes["dst"],
                     valid=shapes["valid"], w=shapes["w"], n=c.n_vertices)

    def lab_struct(shapes):
        return HighwayLabelling(**shapes)

    if shape_name.startswith("update"):
        u = 1024 if shape_name == "update_1k" else 10240
        ush = {
            "src": jax.ShapeDtypeStruct((u,), jnp.int32),
            "dst": jax.ShapeDtypeStruct((u,), jnp.int32),
            "is_del": jax.ShapeDtypeStruct((u,), jnp.bool_),
            "valid": jax.ShapeDtypeStruct((u,), jnp.bool_),
            "w": jax.ShapeDtypeStruct((u,), jnp.int32),
            "is_rew": jax.ShapeDtypeStruct((u,), jnp.bool_),
        }
        u_spec = {k: P(None) for k in ush}

        def step(g, batch, lab):
            g2, lab2, aff = batchhl_update(
                Graph(**g, n=c.n_vertices), BatchUpdate(**batch),
                HighwayLabelling(**lab), improved=c.improved)
            return ({"src": g2.src, "dst": g2.dst, "valid": g2.valid,
                     "w": g2.w},
                    {"landmarks": lab2.landmarks, "dist": lab2.dist,
                     "hub": lab2.hub, "highway": lab2.highway},
                    jnp.sum(aff))
        return cc.Cell(ARCH_ID, shape_name, "update", step,
                       (gsh, ush, lsh), (g_spec, u_spec, lab_spec),
                       (g_spec, lab_spec, P()),
                       dict(updates=u, edges=2 * c.edge_cap,
                            landmarks=c.n_landmarks, train=False))

    if shape_name.startswith("query_1k"):
        b = 1024
        qsh = {"s": jax.ShapeDtypeStruct((b,), jnp.int32),
               "t": jax.ShapeDtypeStruct((b,), jnp.int32)}
        if shape_name == "query_1k_repl":
            # §Perf optimized layout: queries over every axis, graph +
            # labelling replicated (≈160 MB/device) → frontier waves are
            # collective-free; only the final answers gather.
            q_ax = ("pod", "data", "model") if pod else ("data", "model")
            q_spec = {"s": P(q_ax), "t": P(q_ax)}
            g_spec_q = {"src": P(None), "dst": P(None), "valid": P(None),
                        "w": P(None)}
            lab_spec_q = {"landmarks": P(None), "dist": P(None, None),
                          "hub": P(None, None), "highway": P(None, None)}
            out_spec = P(q_ax)
        else:
            q_spec = {"s": P(bax), "t": P(bax)}
            g_spec_q, lab_spec_q, out_spec = g_spec, lab_spec, P(bax)

        def step(g, lab, q):
            return batched_query(Graph(**g, n=c.n_vertices),
                                 HighwayLabelling(**lab), q["s"], q["t"],
                                 max_steps=16)
        return cc.Cell(ARCH_ID, shape_name, "query", step,
                       (gsh, lsh, qsh), (g_spec_q, lab_spec_q, q_spec),
                       out_spec,
                       dict(queries=b, landmarks=c.n_landmarks,
                            train=False))

    # construct
    def step(g, landmarks):
        lab = build_labelling(Graph(**g, n=c.n_vertices), landmarks,
                              max_iters=64)
        return {"landmarks": lab.landmarks, "dist": lab.dist,
                "hub": lab.hub, "highway": lab.highway}
    rsh = jax.ShapeDtypeStruct((c.n_landmarks,), jnp.int32)
    return cc.Cell(ARCH_ID, shape_name, "construct", step,
                   (gsh, rsh), (g_spec, P(None)), lab_spec,
                   dict(landmarks=c.n_landmarks, edges=2 * c.edge_cap,
                        train=False))
