"""Bench-trajectory regression gate (the CI `bench` job's teeth).

    python -m benchmarks.compare benchmarks/baseline.json BENCH_pr3.json \\
        --max-regression 0.25

Compares candidate rows against the committed baseline by name and fails
(exit 1) when any gated latency regresses more than --max-regression, or
when a baseline row vanished from the candidate (coverage loss counts as
a regression). Only rows matching --prefix (comma-separated; default
``ticks/,serve/,tune/`` — the tick trajectory, the serving-pipeline
query-latency percentiles, *and* the autotuner's jnp-vs-tuned sweep
rows), above --min-us, and not ending in
--skip-suffix (default ``/construct`` —
one-shot measurements dominated by trace/compile variance) are gated:
sub-millisecond rows on shared CI runners are noise, and the paper-table
modules are trajectory telemetry, not gates. New candidate rows pass
freely — that is how the trajectory grows.

Rows whose baseline ``derived`` carries ``better=higher`` (the replica
tier's saturation throughput, ``serve/.../max_qps_r<k>``) are gated with
the *inverted* ratio — a drop in sustained qps is the regression — and
bypass the --min-us floor, whose unit they don't share. Their emitter
quantizes the ramp in ×1.3 steps so one step of runner noise (−23%)
stays inside the default 25% budget.

Shared runners are noisy, and not uniformly so: the sub-second jnp tick
rows are scheduler-sensitive (2× swings under transient load) while the
compute-bound interpret-mode pallas rows hold within ~10% run-to-run —
which is why the CI job gates with ``--min-us 500000`` (pallas tick rows
only, jnp rows reported ungated) at the issue-specified 25% budget, on
the min-over-steady-ticks statistic `benchmarks/ticks.py` emits. Two
escape hatches for other topologies: ``--calibrate ROW`` divides every
ratio by a reference row's ratio (gating the relative trajectory when a
runner-*class* change shifts all rows together — pair it with the
uncalibrated ``--max-regression-abs`` backstop, since calibration alone
would also cancel a real across-the-board regression), and the bench
job's artifact is a ready-made replacement baseline: commit it as
`benchmarks/baseline.json` whenever a PR (or a runner-class shift)
legitimately moves the trajectory.
"""
from __future__ import annotations

import argparse
import json
import math
import sys


def load_rows(path: str) -> dict[str, dict]:
    with open(path) as f:
        payload = json.load(f)
    if payload.get("schema") != "repro-bench/v1":
        raise SystemExit(f"{path}: unknown schema {payload.get('schema')!r}"
                         " (expected repro-bench/v1)")
    return {r["name"]: r for r in payload["rows"]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("candidate")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="fail when cand/base - 1 exceeds this (default .25)")
    ap.add_argument("--prefix", default="ticks/,serve/,tune/",
                    help="gate only rows whose name starts with one of "
                         "these comma-separated prefixes")
    ap.add_argument("--skip-suffix", default="/construct",
                    help="report but never gate rows ending in this: "
                         "one-shot construct measurements are dominated "
                         "by trace/compile variance ('' disables)")
    ap.add_argument("--min-us", type=float, default=2000.0,
                    help="gate only rows with baseline latency >= this "
                         "(microseconds); smaller rows are reported but "
                         "not enforced")
    ap.add_argument("--calibrate", default=None, metavar="ROW",
                    help="divide each ratio by this reference row's ratio "
                         "before gating — cancels uniform runner-speed "
                         "shifts so only the relative trajectory is gated "
                         "(the reference row itself is exempt from the "
                         "calibrated check)")
    ap.add_argument("--max-regression-abs", type=float, default=None,
                    metavar="X",
                    help="uncalibrated backstop: additionally fail any "
                         "gated row (calibration row included) whose raw "
                         "ratio exceeds 1+X. Catches uniform regressions "
                         "that calibration would cancel; set it looser "
                         "than --max-regression to absorb runner-class "
                         "spread")
    args = ap.parse_args()

    base = load_rows(args.baseline)
    cand = load_rows(args.candidate)
    prefixes = tuple(p for p in args.prefix.split(",") if p)

    cal = 1.0
    if args.calibrate:
        if args.calibrate not in base or args.calibrate not in cand:
            raise SystemExit(f"--calibrate row {args.calibrate!r} missing "
                             f"from baseline or candidate")
        base_cal = base[args.calibrate]["us_per_call"]
        cand_cal = cand[args.calibrate]["us_per_call"]
        if not (math.isfinite(base_cal) and math.isfinite(cand_cal)
                and base_cal > 0 and cand_cal > 0):
            raise SystemExit(f"--calibrate row {args.calibrate!r} has a "
                             f"non-finite or zero latency (base={base_cal!r},"
                             f" cand={cand_cal!r}) — it would poison every "
                             f"calibrated ratio")
        cal = cand_cal / base_cal
        print(f"calibration: {args.calibrate} ratio {cal:.2f} "
              f"(divided out below)")

    failures: list[str] = []
    print(f"{'row':56s} {'base_us':>12s} {'cand_us':>12s} {'ratio':>7s}")
    for name in sorted(base):
        if not name.startswith(prefixes):
            continue
        b = base[name]["us_per_call"]
        if name not in cand:
            print(f"{name:56s} {b:12.1f} {'MISSING':>12s} {'—':>7s}")
            failures.append(f"{name}: missing from candidate")
            continue
        c = cand[name]["us_per_call"]
        # NaN poisons every comparison below into False (`nan > x` is
        # never true), so a broken emitter used to sail through the
        # gate; treat a non-finite measurement like a missing row.
        if not (math.isfinite(b) and math.isfinite(c)):
            if not (args.skip_suffix and name.endswith(args.skip_suffix)):
                print(f"{name:56s} {str(b):>12s} {str(c):>12s} "
                      f"{'—':>7s}  << NON-FINITE")
                failures.append(f"{name}: non-finite measurement "
                                f"(base={b!r}, cand={c!r})")
            continue
        # Throughput rows (``better=higher`` in the baseline's derived,
        # e.g. the replica tier's serve/.../max_qps_r<k>) invert the
        # ratio so >1 still means "regressed", and skip the --min-us
        # floor — their value is a rate, not microseconds.
        hib = "better=higher" in base[name].get("derived", "")
        if hib:
            raw_ratio = b / c if c else float("inf")
        else:
            raw_ratio = c / b if b else float("inf")
        ratio = raw_ratio / cal
        big = (hib or b >= args.min_us) and not (
            args.skip_suffix and name.endswith(args.skip_suffix))
        unit = "" if hib else "us"
        flag = ""
        if big and name != args.calibrate \
                and ratio > 1.0 + args.max_regression:
            flag = "  << REGRESSION"
            failures.append(f"{name}: {b:.0f}{unit} -> {c:.0f}{unit} "
                            f"({(ratio - 1) * 100:+.0f}% calibrated)")
        elif big and args.max_regression_abs is not None \
                and raw_ratio > 1.0 + args.max_regression_abs:
            flag = "  << ABSOLUTE REGRESSION"
            failures.append(f"{name}: {b:.0f}{unit} -> {c:.0f}{unit} "
                            f"({(raw_ratio - 1) * 100:+.0f}% raw, backstop "
                            f"{args.max_regression_abs:.0%})")
        elif not big:
            flag = "  (not gated)"
        print(f"{name:56s} {b:12.1f} {c:12.1f} {ratio:7.2f}{flag}")
    for name in sorted(set(cand) - set(base)):
        if name.startswith(prefixes):
            print(f"{name:56s} {'—':>12s} "
                  f"{cand[name]['us_per_call']:12.1f} {'new':>7s}")

    if failures:
        print(f"\n{len(failures)} regression(s) beyond "
              f"{args.max_regression:.0%}:", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        raise SystemExit(1)
    print(f"\nOK: no gated row regressed beyond {args.max_regression:.0%}")


if __name__ == "__main__":
    main()
