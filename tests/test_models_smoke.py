"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
shape + finiteness asserts, decode-vs-prefill parity for the LM stack."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import common as cc
from repro.data.synthetic import coherent_gnn_batch
from repro.train.optimizer import AdamWConfig
from repro.train import train_step as ts_lib

LM_ARCHS = ["gemma2-9b", "minitron-4b", "granite-8b",
            "deepseek-v2-lite-16b", "mixtral-8x22b"]
GNN_ARCHS = ["schnet", "dimenet", "mace", "graphcast"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train(arch):
    from repro.models import transformer as tfm
    cfg = cc.get_arch(arch).reduced_config()
    params = tfm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)).astype(np.int32))
    logits = tfm.forward(params, toks, cfg)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    step = jax.jit(ts_lib.make_lm_train_step(cfg, AdamWConfig(lr=3e-3)))
    state = ts_lib.init_train_state(params, AdamWConfig(lr=3e-3))
    batch = {"tokens": toks, "targets": toks}
    losses = []
    for _ in range(6):
        state, aux = step(state, batch)
        losses.append(float(aux["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], f"loss did not decrease: {losses}"


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_decode_matches_prefill(arch):
    """Teacher-forced decode must reproduce the forward logits exactly
    (same params, same positions, cache path vs full path).

    MoE capacity is raised so no token drops: per-group capacity depends on
    the group token count, so drop patterns differ between a 16-token
    forward and 1-token decode steps by design; the parity property being
    tested is the attention/cache path, not capacity truncation."""
    import dataclasses
    from repro.models import transformer as tfm
    cfg = cc.get_arch(arch).reduced_config()
    if cfg.moe:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params = tfm.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    s = 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, s)).astype(np.int32))
    full_logits = tfm.forward(params, toks, cfg)        # [2, s, vocab]

    cshapes = tfm.cache_shapes(cfg, 2, s + 16)
    cache = jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), cshapes,
                         is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    got = []
    for i in range(s):
        logits, cache = tfm.decode_step(params, cache, toks[:, i:i + 1],
                                        jnp.int32(i), cfg)
        got.append(logits)
    got = jnp.stack(got, axis=1)                         # [2, s, vocab]
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["gemma2-9b", "mixtral-8x22b"])
def test_ring_cache_decode_matches_full(arch):
    """The §Perf ring-buffer window cache must be bit-equivalent to the
    full-length cache decode (and to teacher-forced forward) — sliding
    windows only ever read the last `window` positions anyway."""
    import dataclasses
    from repro.models import transformer as tfm
    cfg = cc.get_arch(arch).reduced_config()
    if cfg.moe:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    ring_cfg = dataclasses.replace(cfg, ring_local=True)
    params = tfm.init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    s = 24  # > window (8) so the ring wraps several times
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, s)).astype(np.int32))

    def roll(c):
        cshapes = tfm.cache_shapes(c, 2, 32)
        cache = jax.tree.map(
            lambda sd: jnp.zeros(sd.shape, sd.dtype), cshapes,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        outs = []
        for i in range(s):
            logits, cache = tfm.decode_step(params, cache, toks[:, i:i + 1],
                                            jnp.int32(i), c)
            outs.append(logits)
        return jnp.stack(outs, axis=1)

    full = roll(cfg)
    ring = roll(ring_cfg)
    np.testing.assert_allclose(np.asarray(ring), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train(arch):
    from repro.models import gnn as gnn_lib
    cfg = cc.get_arch(arch).reduced_config()
    batch = coherent_gnn_batch(
        cfg.arch, n_nodes=60, avg_deg=4, d_feat=cfg.d_in, d_out=cfg.d_out,
        n_graphs=4 if cfg.arch != "graphcast" else None)
    params = gnn_lib.init_params(jax.random.PRNGKey(0), cfg)
    out = gnn_lib.forward(params, batch, cfg)
    assert out.shape[0] == 60 and out.shape[-1] == cfg.d_out
    assert bool(jnp.all(jnp.isfinite(out)))

    opt = AdamWConfig(lr=1e-3)
    step = jax.jit(ts_lib.make_generic_train_step(
        lambda p, b: gnn_lib.loss_fn(p, b, cfg), opt))
    state = ts_lib.init_train_state(params, opt)
    losses = []
    for _ in range(8):
        state, aux = step(state, batch)
        losses.append(float(aux["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], f"{arch} loss did not decrease: {losses}"


def test_mace_rotation_equivariance():
    """Scalar outputs must be invariant to a global rotation of positions."""
    from repro.models import gnn as gnn_lib
    cfg = cc.get_arch("mace").reduced_config()
    batch = coherent_gnn_batch("mace", n_nodes=40, avg_deg=4,
                               d_feat=cfg.d_in, d_out=cfg.d_out, n_graphs=4)
    params = gnn_lib.init_params(jax.random.PRNGKey(0), cfg)
    out1 = gnn_lib.forward(params, batch, cfg)
    # random rotation (QR of a random matrix)
    q, _ = np.linalg.qr(np.random.default_rng(3).normal(size=(3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    batch2 = dict(batch)
    batch2["positions"] = batch["positions"] @ jnp.asarray(
        q.astype(np.float32))
    out2 = gnn_lib.forward(params, batch2, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-4, atol=1e-4)


def test_mind_smoke_train_and_serve():
    from repro.models import mind as mind_lib
    cfg = cc.get_arch("mind").reduced_config()
    params = mind_lib.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {
        "hist": jnp.asarray(rng.integers(0, cfg.n_items, (32, cfg.hist_len))
                            .astype(np.int32)),
        "hist_mask": jnp.ones((32, cfg.hist_len), bool),
        "target": jnp.asarray(rng.integers(0, cfg.n_items, 32)
                              .astype(np.int32)),
    }
    opt = AdamWConfig(lr=3e-3)
    step = jax.jit(ts_lib.make_generic_train_step(
        lambda p, b: mind_lib.train_loss(p, b, cfg), opt))
    state = ts_lib.init_train_state(params, opt)
    losses = []
    for _ in range(8):
        state, aux = step(state, batch)
        losses.append(float(aux["loss"]))
    assert losses[-1] < losses[0]

    interests = mind_lib.extract_interests(state["params"], batch["hist"],
                                           batch["hist_mask"], cfg)
    assert interests.shape == (32, cfg.n_interests, cfg.embed_dim)
    sb = {"hist": batch["hist"], "hist_mask": batch["hist_mask"],
          "cands": jnp.asarray(rng.integers(0, cfg.n_items, (32, 11))
                               .astype(np.int32))}
    assert mind_lib.serve_scores(state["params"], sb, cfg).shape == (32, 11)
    rb = {"hist": batch["hist"][:1], "hist_mask": batch["hist_mask"][:1],
          "cands": jnp.asarray(rng.integers(0, cfg.n_items, 333)
                               .astype(np.int32))}
    assert mind_lib.retrieval_scores(state["params"], rb, cfg).shape == (1, 333)


def test_batchhl_reduced_smoke():
    """Paper-arch smoke: reduced service round-trip on CPU."""
    from repro.graphs import generators as gen
    from repro.graphs.coo import from_edges, make_batch
    from repro.core.construct import (build_labelling,
                                      select_landmarks_by_degree)
    from repro.core.batch import batchhl_update

    edges = gen.barabasi_albert(256, 3, seed=0)
    g = from_edges(256, edges, edges.shape[0] + 32)
    landmarks = select_landmarks_by_degree(g, 4)
    lab = build_labelling(g, landmarks)
    assert int(lab.label_size()) > 0
    ups = gen.random_batch_updates(edges, 256, n_ins=8, n_del=8, seed=1)
    batch = make_batch(ups, pad_to=16)
    g2, lab2, aff = batchhl_update(g, batch, lab)
    assert bool(jnp.all(jnp.isfinite(lab2.highway))) or True
    assert lab2.dist.shape == (4, 256)
    assert not bool(jnp.any(jnp.isnan(lab2.dist.astype(jnp.float32))))


def test_generate_loop():
    """Autoregressive sampling: greedy generation is deterministic and
    prefill+decode agree with the training forward pass."""
    from repro.models import transformer as tfm
    from repro.train import serve_step as ss
    cfg = cc.get_arch("granite-8b").reduced_config()
    params = tfm.init_params(jax.random.PRNGKey(3), cfg)
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32))
    out1 = ss.generate(params, cfg, prompt, n_new=6, temperature=0.0)
    out2 = ss.generate(params, cfg, prompt, n_new=6, temperature=0.0)
    assert out1.shape == (2, 14)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    # greedy continuation consistent with the full forward pass (argmax can
    # flip on near-ties between the two numerically-close paths, so require
    # strong majority agreement rather than exact equality)
    full_logits = tfm.forward(params, out1[:, :-1], cfg)
    greedy = np.asarray(jnp.argmax(full_logits[:, 7:], axis=-1))
    agree = float((greedy == np.asarray(out1[:, 8:])).mean())
    assert agree >= 0.75, f"greedy/forward agreement too low: {agree}"
