"""Highway-cover labelling construction: R pruned BFSs as wave relaxation.

The paper builds the labelling with |R| BFSs in O(|R|·|V|). On TPU each BFS
becomes a frontier-synchronous fixpoint of dense edge-relaxation sweeps over
the padded COO arrays; the landmark axis is vmapped (the paper's landmark
parallelism, §6), so all R planes advance in lockstep on the VPU. Sweeps
route through the relaxation engine (`core/engine.py`): pass a `RelaxPlan`
to run the tiled Pallas `edge_relax` kernel, default `plan=None` runs the
jnp segment-min reference.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graphs.coo import Graph, INF_D
from repro.core.engine import RelaxPlan, relax_sweep
from repro.core.labelling import (
    HighwayLabelling, INF_KEY2, key2_dist, key2_hub,
    per_plane_hub_mask,
)


def construct_key2_planes(g: Graph, own: jax.Array,
                          landmarks_full: jax.Array,
                          max_iters: int | None = None,
                          plan: RelaxPlan | None = None) -> jax.Array:
    """Pruned-BFS fixpoints for a plane slice; returns key2 [P, V].

    `own` is the owning landmark of each plane in the slice [P];
    `landmarks_full` is the complete landmark set [R] (the hub flags must
    see every landmark, not just the slice's). Entirely per-plane, so
    `core/shard.py` runs it on shard-local planes.
    """
    p_count = own.shape[0]
    n = g.n
    # Flag semantics are per-plane ("landmark other than r"): landmark r's own
    # plane must not set the flag at r. Handled by seeding r with (0, False)
    # and masking the hub-force at each plane's own landmark.
    dst_is_hub = per_plane_hub_mask(landmarks_full, own, n)

    key2_0 = jnp.full((p_count, n), INF_KEY2, jnp.int32)
    key2_0 = key2_0.at[jnp.arange(p_count), own].set(1)  # (d=0, l=False)

    # vmapped fixpoint with per-plane hub masks.
    def _fix(k0, hub_mask):
        def sweep(k):
            # key2_extend per edge: +2, clamp, clear the l-bit at hub dsts.
            ext = relax_sweep(plan, g, k, 2, INF_KEY2,
                              hub=hub_mask, clear_bit=1)
            return jnp.minimum(k, ext)

        def cond(state):
            k, changed, it = state
            lim = jnp.asarray(max_iters if max_iters is not None else g.n + 1)
            return changed & (it < lim)

        def body(state):
            k, _, it = state
            nk = sweep(k)
            return nk, jnp.any(nk != k), it + 1

        k, _, _ = jax.lax.while_loop(
            cond, body, (k0, jnp.asarray(True), jnp.asarray(0)))
        return k

    return jax.vmap(_fix)(key2_0, dst_is_hub)


def build_labelling(g: Graph, landmarks: jax.Array,
                    max_iters: int | None = None,
                    plan: RelaxPlan | None = None) -> HighwayLabelling:
    """Construct the minimal highway-cover labelling for G."""
    r_count = landmarks.shape[0]
    key2 = construct_key2_planes(g, landmarks, landmarks, max_iters, plan)

    dist = jnp.minimum(key2_dist(key2), INF_D)
    hub = key2_hub(key2) & (dist < INF_D)
    # highway[i, j] = dist[i, landmarks[j]]
    highway = dist[jnp.arange(r_count)[:, None], landmarks[None, :]]
    return HighwayLabelling(landmarks.astype(jnp.int32), dist, hub, highway)


@partial(jax.jit, static_argnames=("k",))
def select_landmarks_by_degree(g: Graph, k: int) -> jax.Array:
    """Paper's landmark policy: top-k highest-degree vertices."""
    deg = jax.ops.segment_sum(g.valid.astype(jnp.int32), g.dst,
                              num_segments=g.n)
    _, idx = jax.lax.top_k(deg, k)
    return idx.astype(jnp.int32)
