"""Property tests for the paper's core: BatchHL vs a from-scratch oracle.

Invariants under random graphs × random batches (hypothesis-driven):
  * construction reproduces the oracle's minimal highway-cover labelling
    (Theorem in [17]; distances, hub flags, label masks, highway),
  * BatchHL (both BHL and BHL+) maintains exactly the minimal labelling of
    G' (Theorem 5.21: correctness + minimality),
  * batch search supersets: improved ⊇ LD-affected (Lemma 5.18),
    basic ⊇ affected (Lemma 5.8), and |improved| ≤ |basic| (Table 5),
  * queries are exact (paper §4),
  * no-op batches and insert+delete round-trips leave the labelling fixed.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests only; optional dep
pytestmark = pytest.mark.slow  # property tests: full CI job only
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.graphs import generators as gen
from repro.graphs.coo import from_edges, make_batch, to_numpy_adj, INF_D
from repro.core.construct import build_labelling
from repro.core.batch import (batchhl_update, batchhl_update_split,
                              batch_search_basic, batch_search_improved,
                              uhl_update)
from repro.core.query import batched_query
from repro.core import ref

SETTINGS = dict(deadline=None, max_examples=20,
                suppress_health_check=[HealthCheck.too_slow,
                                       HealthCheck.data_too_large])


def _setup(seed: int, n: int, n_land: int):
    edges = gen.random_connected(n, extra_edges=n // 2, seed=seed)
    g = from_edges(n, edges, edges.shape[0] + 64)
    deg = np.zeros(n)
    for u, v in edges:
        deg[u] += 1
        deg[v] += 1
    landmarks = np.argsort(-deg, kind="stable")[:n_land].astype(np.int32)
    lab = build_labelling(g, jnp.asarray(landmarks))
    return edges, g, landmarks, lab


def _oracle_labelling(adj, n, landmarks):
    return ref.minimal_labelling(adj, n, list(landmarks))


def _assert_matches_oracle(lab, adj, n, landmarks):
    od, oh, ohw, omask = _oracle_labelling(adj, n, landmarks)
    jd = np.asarray(lab.dist)
    jh = np.asarray(lab.hub)
    jm = np.asarray(lab.label_mask())
    jhw = np.asarray(lab.highway)
    for i in range(len(landmarks)):
        for v in range(n):
            want = od[i][v] if od[i][v] != ref.INF else int(INF_D)
            assert jd[i, v] == want, (i, v, jd[i, v], want)
            if od[i][v] != ref.INF:
                assert bool(jh[i, v]) == oh[i][v], (i, v)
            assert bool(jm[i, v]) == omask[i][v], (i, v)
        for j in range(len(landmarks)):
            want = ohw[i][j] if ohw[i][j] != ref.INF else int(INF_D)
            assert jhw[i, j] == want


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 48),
       n_land=st.integers(1, 5))
def test_construction_matches_oracle(seed, n, n_land):
    edges, g, landmarks, lab = _setup(seed, n, min(n_land, n))
    _assert_matches_oracle(lab, to_numpy_adj(g), n, landmarks)


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(10, 40),
       n_ins=st.integers(0, 5), n_del=st.integers(0, 5),
       improved=st.booleans())
def test_batch_update_maintains_minimal_labelling(seed, n, n_ins, n_del,
                                                  improved):
    edges, g, landmarks, lab = _setup(seed, n, 3)
    ups = gen.random_batch_updates(edges, n, n_ins=n_ins, n_del=n_del,
                                   seed=seed + 1)
    batch = make_batch(ups, pad_to=max(n_ins + n_del, 1))
    g2, lab2, _ = batchhl_update(g, batch, lab, improved=improved)
    adj2 = ref.apply_updates(to_numpy_adj(g), ups)
    # graph update itself is correct
    assert to_numpy_adj(g2) == adj2
    # labelling is the minimal labelling of G' (Thm 5.21)
    _assert_matches_oracle(lab2, adj2, n, landmarks)


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(10, 36))
def test_affected_supersets_and_pruning(seed, n):
    edges, g, landmarks, lab = _setup(seed, n, 3)
    ups = gen.random_batch_updates(edges, n, n_ins=3, n_del=3, seed=seed + 1)
    batch = make_batch(ups, pad_to=6)
    from repro.graphs.coo import apply_batch
    g2 = apply_batch(g, batch)
    adj, adj2 = to_numpy_adj(g), to_numpy_adj(g2)

    aff_b = np.asarray(batch_search_basic(g, g2, batch, lab))
    aff_i = np.asarray(batch_search_improved(g, g2, batch, lab))
    for i, r in enumerate(landmarks):
        full = ref.affected_set(adj, adj2, n, int(r))
        ld = ref.ld_affected_set(adj, adj2, n, list(landmarks), int(r))
        assert all(aff_b[i, v] for v in full), "Lemma 5.8 violated"
        assert all(aff_i[i, v] for v in ld), "Lemma 5.18 violated"
        # improved search prunes at least as hard as basic (Table 5)
        assert aff_i[i].sum() <= aff_b[i].sum()


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(10, 36))
def test_queries_exact_after_update(seed, n):
    edges, g, landmarks, lab = _setup(seed, n, 3)
    ups = gen.random_batch_updates(edges, n, n_ins=2, n_del=3, seed=seed + 9)
    batch = make_batch(ups, pad_to=5)
    g2, lab2, _ = batchhl_update(g, batch, lab, improved=True)
    adj2 = to_numpy_adj(g2)
    rng = np.random.default_rng(seed)
    qs = rng.integers(0, n, 16).astype(np.int32)
    qt = rng.integers(0, n, 16).astype(np.int32)
    got = np.asarray(batched_query(g2, lab2, jnp.asarray(qs),
                                   jnp.asarray(qt)))
    for k in range(16):
        want = ref.pair_distance(adj2, n, int(qs[k]), int(qt[k]))
        want = 0 if qs[k] == qt[k] else want
        want = int(INF_D) if want == ref.INF else want
        assert got[k] == want, (qs[k], qt[k], got[k], want)


def test_noop_batch_is_identity():
    edges, g, landmarks, lab = _setup(3, 24, 3)
    batch = make_batch([(0, 1, False)], pad_to=4)
    batch = batch.__class__(batch.src, batch.dst, batch.is_del,
                            jnp.zeros_like(batch.valid))  # all padding
    g2, lab2, aff = batchhl_update(g, batch, lab)
    assert not bool(jnp.any(aff))
    assert bool(jnp.all(lab2.dist == lab.dist))
    assert bool(jnp.all(lab2.hub == lab.hub))


def test_insert_then_delete_roundtrip():
    edges, g, landmarks, lab = _setup(5, 24, 3)
    ups = gen.random_batch_updates(edges, 24, n_ins=3, n_del=0, seed=11)
    batch = make_batch(ups, pad_to=3)
    g2, lab2, _ = batchhl_update(g, batch, lab)
    rev = make_batch([(u, v, True) for (u, v, _) in ups], pad_to=3)
    g3, lab3, _ = batchhl_update(g2, rev, lab2)
    assert bool(jnp.all(lab3.dist == lab.dist))
    assert bool(jnp.all(lab3.hub == lab.hub))
    assert bool(jnp.all(lab3.highway == lab.highway))


def test_split_and_unit_variants_agree():
    """BHL, BHL^s and UHL+ must all land on the same minimal labelling."""
    edges, g, landmarks, lab = _setup(7, 28, 3)
    ups = gen.random_batch_updates(edges, 28, n_ins=3, n_del=3, seed=13)
    batch = make_batch(ups, pad_to=6)
    _, lab_b, _ = batchhl_update(g, batch, lab, improved=True)
    _, lab_s, _ = batchhl_update_split(g, batch, lab, improved=True)
    _, lab_u, _ = uhl_update(g, batch, lab, improved=True)
    for a, b in ((lab_b, lab_s), (lab_b, lab_u)):
        assert bool(jnp.all(a.dist == b.dist))
        assert bool(jnp.all(a.hub == b.hub))


def test_disconnection_and_reconnection():
    """Deleting a bridge makes distances INF; reinserting restores them."""
    # path graph 0-1-2-3 with landmark 0
    edges = np.array([[0, 1], [1, 2], [2, 3]], np.int32)
    g = from_edges(4, edges, 8)
    lab = build_labelling(g, jnp.asarray([0], jnp.int32))
    batch = make_batch([(1, 2, True)], pad_to=1)
    g2, lab2, _ = batchhl_update(g, batch, lab)
    assert int(lab2.dist[0, 2]) == int(INF_D)
    assert int(lab2.dist[0, 3]) == int(INF_D)
    back = make_batch([(1, 2, False)], pad_to=1)
    g3, lab3, _ = batchhl_update(g2, back, lab2)
    assert int(lab3.dist[0, 2]) == 2
    assert int(lab3.dist[0, 3]) == 3
