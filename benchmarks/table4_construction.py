"""Paper Table 4: construction time, query time, labelling size —
BHL⁺ vs the pure online-search baseline (BiBFS, no labelling)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.query import batched_query, bounded_bibfs, query_upper_bound
from repro.core.labelling import HighwayLabelling
from repro.graphs.coo import INF_D
from benchmarks import common as cm

DATASETS = ("ba_2k", "ba_10k", "ba_20k", "er_5k")
N_QUERIES = 256


def run(datasets=DATASETS) -> list[str]:
    rows = []
    rng = np.random.default_rng(3)
    for ds in datasets:
        inst = cm.build_instance(ds)
        rows.append(cm.emit(f"table4/{ds}/construction", inst.construct_s,
                            f"V={inst.n},E={inst.edges.shape[0]}"))
        size = int(inst.lab.label_size())
        bytes_ = size * 8  # (landmark id, distance) pairs
        rows.append(cm.emit(f"table4/{ds}/label_size", 0.0,
                            f"entries={size},bytes={bytes_},"
                            f"avg_per_vertex={size / inst.n:.2f}"))

        qs = jnp.asarray(rng.integers(0, inst.n, N_QUERIES), jnp.int32)
        qt = jnp.asarray(rng.integers(0, inst.n, N_QUERIES), jnp.int32)
        t_q = cm.timeit(lambda: batched_query(inst.g, inst.lab, qs, qt))
        rows.append(cm.emit(f"table4/{ds}/query_BHL+", t_q / N_QUERIES,
                            f"batch={N_QUERIES}"))

        # BiBFS baseline: unbounded bidirectional search, no labelling
        # (bound = INF ⇒ no highway pruning; landmarks kept traversable
        # by passing an empty landmark set).
        empty = jnp.zeros((0,), jnp.int32)
        t_bibfs = cm.timeit(
            lambda: bounded_bibfs(inst.g, empty, qs, qt,
                                  jnp.full((N_QUERIES,), INF_D), 64))
        rows.append(cm.emit(f"table4/{ds}/query_BiBFS",
                            t_bibfs / N_QUERIES, f"batch={N_QUERIES}"))

        # upper-bound-only path (labels without the sparsified search)
        t_ub = cm.timeit(
            lambda: query_upper_bound(inst.lab, qs, qt))
        rows.append(cm.emit(f"table4/{ds}/query_bound_only",
                            t_ub / N_QUERIES, f"batch={N_QUERIES}"))
    return rows


if __name__ == "__main__":
    run()
