"""Highway-cover labelling state and landmark-length encodings.

The paper's label lists are realized as dense per-landmark planes:

  dist[R, V]  int32   d_G(r, v)                      (INF_D if unreachable)
  hub[R, V]   bool    landmark flag of d^L_G(r, v):  True iff some shortest
                      r->v path passes through a landmark other than r
                      (endpoints count, per the paper's ⊕ operator)
  highway[R,R] int32  δ_H

The minimal highway-cover labelling (Lemma 5.14) is the masked set
{(r, v) : dist finite ∧ ¬hub}; `label_size` counts it exactly.

Landmark lengths (d, l) and extended landmark lengths (d, l, e) are encoded
as integers so lexicographic tuple order (True < False on flags) is integer
order and `min` implements tuple minimization on the VPU:

  key2(d, l)    = 2*d + (1 - l)             # l ∈ {0,1}, 1 = True
  key4(d, l, e) = 4*d + 2*(1 - l) + (1 - e)

The paper's path-extension operator (d,l) ⊕ w becomes key arithmetic:
add the step, then clear the l-bit when w is a landmark.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.graphs.coo import INF_D

INF_KEY2 = jnp.int32(2) * INF_D + 1
INF_KEY4 = jnp.int32(4) * INF_D + 3


# --- key2: landmark length (d, l) ------------------------------------------

def key2_make(d, l):
    return 2 * d + (1 - l.astype(jnp.int32))


def key2_dist(key2):
    return key2 >> 1


def key2_hub(key2):
    return (key2 & 1) == 0


def key2_extend(key2, dst_is_hub, inf=INF_KEY2, w=1):
    """(d,l) ⊕ edge : +w step; force l=True when the head is a landmark
    (≠ r). `w` is the edge weight (1 = the unweighted metric); the add
    saturates at `inf` (non-negative operands, so int32 wrap < 0)."""
    s = key2 + 2 * w
    out = jnp.minimum(jnp.where(s < 0, inf, s), inf)
    out = jnp.where(dst_is_hub, out & ~jnp.int32(1), out)
    return out


# --- key4: extended landmark length (d, l, e) -------------------------------

def key4_make(d, l, e):
    return 4 * d + 2 * (1 - l.astype(jnp.int32)) + (1 - e.astype(jnp.int32))


def key4_from_key2(key2, e):
    """Lift (d,l) to (d,l,e)."""
    return 2 * key2 + (1 - e.astype(jnp.int32))


def key4_extend(key4, dst_is_hub, inf=INF_KEY4, w=1):
    """((d,l) ⊕ edge, e): +w step keeps the deletion flag. Saturating,
    like `key2_extend`."""
    s = key4 + 4 * w
    out = jnp.minimum(jnp.where(s < 0, inf, s), inf)
    out = jnp.where(dst_is_hub, out & ~jnp.int32(2), out)
    return out


def key4_beta(key2_g):
    """β(r, v) = (d^L_G(r,v), True): the improved-search pruning bound."""
    return 2 * key2_g  # e=True encodes as +0


@partial(jax.tree_util.register_dataclass,
         data_fields=("landmarks", "dist", "hub", "highway"), meta_fields=())
@dataclasses.dataclass(frozen=True)
class HighwayLabelling:
    landmarks: jax.Array  # int32[R] vertex ids
    dist: jax.Array       # int32[R, V]
    hub: jax.Array        # bool[R, V]
    highway: jax.Array    # int32[R, R]

    @property
    def num_landmarks(self) -> int:
        return self.landmarks.shape[0]

    def key2(self) -> jax.Array:
        """[R, V] encoded landmark distances d^L_G(r, ·)."""
        return key2_make(self.dist, self.hub)

    def label_mask(self) -> jax.Array:
        """[R, V] True where the minimal labelling stores an r-label."""
        mask = (self.dist < INF_D) & ~self.hub
        # Landmarks store no labels (their distances live in the highway),
        # except the trivial self entry, which we exclude from counting too.
        v_ids = jnp.arange(self.dist.shape[1])
        is_landmark_v = jnp.any(v_ids[None, :] == self.landmarks[:, None],
                                axis=0)
        return mask & ~is_landmark_v[None, :]

    def label_size(self) -> jax.Array:
        return jnp.sum(self.label_mask())

    def label_values(self) -> jax.Array:
        """[R, V] label distances, INF_D where no label exists."""
        return jnp.where(self.label_mask(), self.dist, INF_D)


def grow_labelling(lab: HighwayLabelling, new_n: int) -> HighwayLabelling:
    """Widen the labelling planes to `new_n` vertices (grow-in-place).

    New columns are seeded exactly as a fresh construction at the larger
    size would leave an isolated vertex: dist INF_D (the pruned-BFS
    fixpoint never reaches it, and key2_dist(INF_KEY2) == INF_D), hub
    False (the flag is masked to finite distances). The landmark set and
    the highway are untouched — growth never adds landmarks, and no
    existing distance changes until a batch actually wires the new
    vertices in. Bit-parity with fresh construction at `new_n` is pinned
    by `tests/test_growth.py`.
    """
    old_n = lab.dist.shape[1]
    if new_n < old_n:
        raise ValueError(f"grow_labelling cannot shrink: {old_n}->{new_n}")
    if new_n == old_n:
        return lab
    r = lab.dist.shape[0]
    pad_d = jnp.full((r, new_n - old_n), INF_D, lab.dist.dtype)
    pad_h = jnp.zeros((r, new_n - old_n), bool)
    return HighwayLabelling(lab.landmarks,
                            jnp.concatenate([lab.dist, pad_d], axis=1),
                            jnp.concatenate([lab.hub, pad_h], axis=1),
                            lab.highway)


def landmark_onehot(landmarks: jax.Array, n: int) -> jax.Array:
    """bool[V]: vertex is a landmark."""
    v_ids = jnp.arange(n)
    return jnp.any(v_ids[None, :] == landmarks[:, None], axis=0)


def per_plane_hub_mask(landmarks_full: jax.Array, own: jax.Array,
                       n: int) -> jax.Array:
    """[P, V] True where vertex is a landmark *other than* the plane's own.

    The hub-flag rule of the ⊕ operator, shared by construction, search,
    and repair. `landmarks_full` is the complete landmark set [R]; `own`
    is the owning landmark of each plane in this (possibly sharded) plane
    slice [P] — the split lets `core/shard.py` evaluate the mask on a
    local slice of planes while still flagging every global landmark.
    """
    is_hub_v = landmark_onehot(landmarks_full, n)
    own_oh = jax.nn.one_hot(own, n, dtype=bool)
    return jnp.broadcast_to(is_hub_v, own_oh.shape) & ~own_oh
