"""The versioned-snapshot serving pipeline (DESIGN.md §5): chunked
updates are bit-identical to the monolithic BatchHL step, pipelined
serving answers are exact at the version each query was served, full
checkpoints resume the loop exactly, and the scenario registry / mesh
validation behave.

The forced-8-device coverage lives in `repro.core.snapshot._selftest`
(subprocess, slow-marked below) — the in-process tests here run on
whatever devices the session has (1 in plain CI, 8 in the mesh job)."""
from __future__ import annotations

import os
import subprocess
import sys
from types import SimpleNamespace

import numpy as np
import pytest
import jax.numpy as jnp

from repro.graphs import generators as gen
from repro.graphs.coo import apply_batch, from_edges, make_batch, \
    to_numpy_adj
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.batch import batchhl_update
from repro.core.engine import RelaxEngine
from repro.core.query import batched_query
from repro.core.shard import validate_landmark_sharding
from repro.core.snapshot import (Snapshot, SnapshotStore, pipelined_update,
                                 restore_snapshot, run_pipelined_update,
                                 save_snapshot)
from repro.checkpoint import manager as ckpt
from repro.data.scenarios import SCENARIOS, get_scenario
from repro.launch.serve import ServeConfig, ServeLoop

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _instance(seed=3, n=150, extra=200, r=8):
    edges = gen.random_connected(n, extra_edges=extra, seed=seed)
    g = from_edges(n, edges, edges.shape[0] + 64)
    landmarks = select_landmarks_by_degree(g, r)
    lab = build_labelling(g, landmarks)
    ups = gen.random_batch_updates(edges, n, n_ins=8, n_del=8, seed=9)
    return g, lab, make_batch(ups, pad_to=16)


# --- chunked update ≡ monolithic update ------------------------------------

@pytest.mark.parametrize("improved", [True, False])
@pytest.mark.parametrize("chunk_sweeps", [1, 3])
def test_pipelined_update_matches_monolithic(improved, chunk_sweeps):
    g, lab, batch = _instance()
    gm, labm, affm = batchhl_update(g, batch, lab, improved=improved)
    nxt, aff = run_pipelined_update(pipelined_update(
        Snapshot(0, g, lab, None), batch, improved=improved,
        chunk_sweeps=chunk_sweeps))
    assert nxt.version == 1
    np.testing.assert_array_equal(np.asarray(aff), np.asarray(affm))
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(
            np.asarray(getattr(nxt.labelling, f)),
            np.asarray(getattr(labm, f)))
    np.testing.assert_array_equal(np.asarray(nxt.graph.valid),
                                  np.asarray(gm.valid))


def test_pipelined_update_pallas_plan():
    """The chunked path composes with a prepared Pallas tiling."""
    g, lab, batch = _instance()
    gm, labm, affm = batchhl_update(g, batch, lab)
    g_next = apply_batch(g, batch)
    plan = RelaxEngine(backend="pallas", block_v=32,
                       shards=2).prepare(g_next)
    nxt, aff = run_pipelined_update(pipelined_update(
        Snapshot(0, g, lab, None), batch, plan=plan, g_new=g_next))
    np.testing.assert_array_equal(np.asarray(aff), np.asarray(affm))
    np.testing.assert_array_equal(np.asarray(nxt.labelling.dist),
                                  np.asarray(labm.dist))


def test_pipelined_update_mesh_matches():
    """Mesh chunks (maintenance plane grouping) ≡ unsharded monolith."""
    from repro.launch.mesh import make_host_mesh
    g, lab, batch = _instance()
    gm, labm, affm = batchhl_update(g, batch, lab)
    nxt, aff = run_pipelined_update(pipelined_update(
        Snapshot(0, g, lab, None), batch, mesh=make_host_mesh(),
        chunk_sweeps=2))
    np.testing.assert_array_equal(np.asarray(aff), np.asarray(affm))
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(
            np.asarray(getattr(nxt.labelling, f)),
            np.asarray(getattr(labm, f)))


# --- fused megakernel chunks ≡ monolithic update ---------------------------

@pytest.mark.parametrize("improved", [True, False])
@pytest.mark.parametrize("chunk_sweeps", [1, 2, 3])
def test_fused_update_matches_monolithic(improved, chunk_sweeps):
    """The fused path (seed + K sweeps in one dispatch, later chunks
    donating the labelling plane) is bit-identical to `batchhl_update`
    for every chunk size × variant."""
    g, lab, batch = _instance()
    gm, labm, affm = batchhl_update(g, batch, lab, improved=improved)
    nxt, aff = run_pipelined_update(pipelined_update(
        Snapshot(0, g, lab, None), batch, improved=improved,
        chunk_sweeps=chunk_sweeps, fused=True))
    np.testing.assert_array_equal(np.asarray(aff), np.asarray(affm))
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(
            np.asarray(getattr(nxt.labelling, f)),
            np.asarray(getattr(labm, f)))
    np.testing.assert_array_equal(np.asarray(nxt.graph.valid),
                                  np.asarray(gm.valid))


@pytest.mark.parametrize("impl", ["kernel", "sorted"])
def test_fused_update_pallas_plans(impl):
    """Fused chunks compose with both Pallas plan impls: the tiled
    kernel tiling and the autotuned dst-sorted twin."""
    g, lab, batch = _instance()
    gm, labm, affm = batchhl_update(g, batch, lab)
    g_next = apply_batch(g, batch)
    if impl == "kernel":
        engine = RelaxEngine(backend="pallas", block_v=32, shards=2)
    else:
        engine = RelaxEngine(backend="pallas", block_v=32, autotune=True)
    plan = engine.prepare(g_next)
    assert plan.impl == impl
    nxt, aff = run_pipelined_update(pipelined_update(
        Snapshot(0, g, lab, None), batch, plan=plan, g_new=g_next,
        fused=True, chunk_sweeps=2))
    np.testing.assert_array_equal(np.asarray(aff), np.asarray(affm))
    np.testing.assert_array_equal(np.asarray(nxt.labelling.dist),
                                  np.asarray(labm.dist))


def test_fused_update_mesh_matches():
    """Fused mesh twins (pmax convergence + donated mesh plane) ≡ the
    unsharded monolith on this session's device mesh; the full
    factorization sweep lives in `repro.core.snapshot._selftest`."""
    from repro.launch.mesh import make_host_mesh
    g, lab, batch = _instance()
    gm, labm, affm = batchhl_update(g, batch, lab)
    nxt, aff = run_pipelined_update(pipelined_update(
        Snapshot(0, g, lab, None), batch, mesh=make_host_mesh(),
        chunk_sweeps=2, fused=True))
    np.testing.assert_array_equal(np.asarray(aff), np.asarray(affm))
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(
            np.asarray(getattr(nxt.labelling, f)),
            np.asarray(getattr(labm, f)))


def test_fused_donation_safety():
    """Donation must never alias live inputs: running the identical
    fused update twice from the same snapshot gives the same bits, and
    the input labelling survives both runs untouched (a donated-buffer
    reuse would corrupt one or the other)."""
    g, lab, batch = _instance()
    before = {f: np.array(getattr(lab, f)) for f in ("dist", "hub",
                                                     "highway")}
    outs = []
    for _ in range(2):
        nxt, aff = run_pipelined_update(pipelined_update(
            Snapshot(0, g, lab, None), batch, fused=True, chunk_sweeps=1))
        outs.append((np.asarray(aff),
                     {f: np.asarray(getattr(nxt.labelling, f))
                      for f in before}))
    np.testing.assert_array_equal(outs[0][0], outs[1][0])
    for f in before:
        np.testing.assert_array_equal(outs[0][1][f], outs[1][1][f])
        np.testing.assert_array_equal(np.asarray(getattr(lab, f)),
                                      before[f])


# --- pipelined serving: exact at the served version ------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_pipeline_serving_exact_at_version(backend):
    """Every answered query equals the synchronous `batched_query` at the
    snapshot version it was served — the staleness contract."""
    cfg = ServeConfig(n=200, deg=3, landmarks=8, batches=3, batch_size=20,
                      queries=24, qps=5000.0, microbatch=8, pipeline=True,
                      backend=backend, block_v=64, tile_shards=2,
                      quiet=True, keep_history=True)
    rep = ServeLoop(cfg).run()
    assert sum(m.qs.shape[0] for m in rep.microbatches) == 3 * 24
    for m in rep.microbatches:
        snap = rep.history[m.version]
        want = batched_query(snap.graph, snap.labelling,
                             jnp.asarray(m.qs), jnp.asarray(m.qt))
        np.testing.assert_array_equal(m.answers, np.asarray(want))
    # the pipeline actually overlapped: some answers were served against
    # the stale committed snapshot while the update was in flight
    assert any(m.staleness == 1 for m in rep.microbatches)
    assert all(m.staleness in (0, 1) for m in rep.microbatches)


def test_pipeline_and_sync_commit_identical_labellings():
    """Same stream, both modes: per-tick committed state is bit-equal
    (the pipeline changes *when* queries are answered, never the data)."""
    base = dict(n=200, deg=3, landmarks=8, batches=3, batch_size=20,
                queries=16, qps=5000.0, microbatch=8, quiet=True,
                keep_history=True)
    rep_s = ServeLoop(ServeConfig(**base, pipeline=False)).run()
    rep_p = ServeLoop(ServeConfig(**base, pipeline=True)).run()
    assert rep_s.final.version == rep_p.final.version == 3
    for v in range(4):
        for f in ("dist", "hub", "highway"):
            np.testing.assert_array_equal(
                np.asarray(getattr(rep_s.history[v].labelling, f)),
                np.asarray(getattr(rep_p.history[v].labelling, f)))
        np.testing.assert_array_equal(
            np.asarray(rep_s.history[v].graph.valid),
            np.asarray(rep_p.history[v].graph.valid))
    # identical query streams, answered in full by both modes
    np.testing.assert_array_equal(
        np.concatenate([m.qs for m in rep_s.microbatches]),
        np.concatenate([m.qs for m in rep_p.microbatches]))
    # sync never serves stale; pipeline reports staleness honestly
    assert all(m.staleness == 0 for m in rep_s.microbatches)


@pytest.mark.slow
def test_pipeline_selftest_multidevice():
    """Chunked-update parity on every (data, model) factorization of an
    8-device CPU mesh × both backends, plus pipelined mesh serving with
    every answer re-derived at its served version."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.core.snapshot"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "pipeline selftest OK on 8 device(s)" in out.stdout, out.stdout


# --- checkpoint / resume ---------------------------------------------------

def test_save_restore_resume_exact(tmp_path):
    """Interrupt after 2 of 4 ticks, resume in a fresh loop: identical
    final labelling, edge set, version, and per-query answers."""
    base = dict(n=200, deg=3, landmarks=8, batches=4, batch_size=20,
                queries=12, qps=5000.0, microbatch=8, quiet=True, seed=3)
    rep_a = ServeLoop(ServeConfig(**base, ckpt_dir=str(tmp_path / "a"))).run()
    ServeLoop(ServeConfig(**{**base, "batches": 2},
                          ckpt_dir=str(tmp_path / "b"))).run()
    rep_b = ServeLoop(ServeConfig(**base, ckpt_dir=str(tmp_path / "b"),
                                  resume=True)).run()
    assert rep_a.final.version == rep_b.final.version == 4
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(
            np.asarray(getattr(rep_a.final.labelling, f)),
            np.asarray(getattr(rep_b.final.labelling, f)))
    # same edge *set* (capacities may differ — the short first leg sized
    # its padding for fewer ticks; content is what resume must preserve)
    assert to_numpy_adj(rep_a.final.graph) == to_numpy_adj(rep_b.final.graph)
    a_tail = [m for m in rep_a.microbatches if m.tick >= 2]
    b_tail = [m for m in rep_b.microbatches if m.tick >= 2]
    np.testing.assert_array_equal(
        np.concatenate([m.qs for m in a_tail]),
        np.concatenate([m.qs for m in b_tail]))
    np.testing.assert_array_equal(
        np.concatenate([m.answers for m in a_tail]),
        np.concatenate([m.answers for m in b_tail]))


def test_checkpoint_carries_graph_state(tmp_path):
    """The full-state checkpoint restores graph topology, not just the
    labelling — and an old labelling-only checkpoint errors clearly."""
    g, lab, batch = _instance()
    g2, lab2, _ = batchhl_update(g, batch, lab)
    snap = Snapshot(5, g2, lab2, None)
    save_snapshot(str(tmp_path / "full"), snap)
    back = restore_snapshot(str(tmp_path / "full"))
    assert back.version == 5 and back.graph.n == g2.n
    np.testing.assert_array_equal(np.asarray(back.graph.src),
                                  np.asarray(g2.src))
    np.testing.assert_array_equal(np.asarray(back.graph.valid),
                                  np.asarray(g2.valid))
    np.testing.assert_array_equal(np.asarray(back.labelling.dist),
                                  np.asarray(lab2.dist))

    ckpt.save(str(tmp_path / "old"), 1,
              {"dist": lab.dist, "hub": lab.hub, "highway": lab.highway,
               "landmarks": lab.landmarks})
    with pytest.raises(FileNotFoundError, match="graph state"):
        restore_snapshot(str(tmp_path / "old"))


def test_snapshot_store_contract():
    g, lab, _ = _instance()
    store = SnapshotStore(Snapshot(0, g, lab, None))
    assert store.version == 0
    with pytest.raises(ValueError, match="contiguous"):
        store.commit(Snapshot(2, g, lab, None))
    store.commit(Snapshot(1, g, lab, None))
    assert store.committed.version == 1


# --- engine plan keying ----------------------------------------------------

def test_engine_plan_cache_keeps_two_snapshots():
    """Alternating prepares between two live snapshots (the pipeline's
    committed-N / building-N+1 pattern) hit the keyed cache instead of
    retiling every time."""
    g, lab, batch = _instance()
    g2 = apply_batch(g, batch)
    engine = RelaxEngine(backend="pallas", block_v=32)
    p0 = engine.prepare(g)
    p1 = engine.prepare(g2)
    assert engine.retile_count == 2 and engine.plan_cache_hits == 0
    p0b = engine.prepare(g)
    p1b = engine.prepare(g2)
    assert engine.retile_count == 2, "keyed cache missed a live snapshot"
    assert engine.plan_cache_hits == 2
    assert p0b.tiles is p0.tiles and p1b.tiles is p1.tiles


# --- scenarios -------------------------------------------------------------

def test_scenario_registry():
    assert set(SCENARIOS) == {"mixed", "insert-heavy", "delete-heavy",
                              "bursty", "skewed", "growth", "traffic"}
    ins, dele, rew = get_scenario("growth").update_counts(0, 100)
    assert (ins, dele, rew) == (100, 0, 0)  # pure insertions
    with pytest.raises(ValueError, match="unknown scenario"):
        get_scenario("nope")
    ins, dele, rew = get_scenario("insert-heavy").update_counts(0, 100)
    assert (ins, dele, rew) == (90, 10, 0)
    ins, dele, rew = get_scenario("delete-heavy").update_counts(0, 100)
    assert (ins, dele, rew) == (10, 90, 0)
    bursty = get_scenario("bursty")
    assert bursty.update_counts(0, 100) == (50, 50, 0)   # burst tick
    assert sum(bursty.update_counts(1, 100)) == 10       # trickle tick
    assert bursty.max_inserts(3, 100) >= 55
    traffic = get_scenario("traffic")
    ins, dele, rew = traffic.update_counts(1, 100)
    assert rew == 75 and ins + dele == 25 and traffic.max_weight == 8
    # every 4th tick (tick > 0) is weight-change-only: zero slot churn
    assert traffic.update_counts(4, 100) == (0, 0, 100)
    assert traffic.update_counts(0, 100)[2] == 75
    rng = np.random.default_rng(0)
    qs, qt = get_scenario("skewed").sample_queries(rng, 50, 256)
    assert qs.min() >= 0 and qs.max() < 50 and qt.max() < 50
    # skew concentrates sources on low (hub) ids
    assert np.mean(qs < 5) > np.mean(qt < 5)


def test_scenarios_run_end_to_end():
    for name in ("insert-heavy", "delete-heavy", "bursty", "skewed"):
        cfg = ServeConfig(n=120, deg=3, landmarks=4, batches=2,
                          batch_size=12, queries=8, qps=5000.0,
                          microbatch=8, scenario=name, pipeline=True,
                          quiet=True, keep_history=True)
        rep = ServeLoop(cfg).run()
        assert rep.final.version == 2
        for m in rep.microbatches:
            snap = rep.history[m.version]
            want = batched_query(snap.graph, snap.labelling,
                                 jnp.asarray(m.qs), jnp.asarray(m.qt))
            np.testing.assert_array_equal(m.answers, np.asarray(want))


# --- landmark-grouping validation ------------------------------------------

def test_validate_landmark_sharding_names_failing_grouping():
    mesh24 = SimpleNamespace(shape={"data": 2, "model": 4})
    validate_landmark_sharding(mesh24, 16)               # both groupings ok
    with pytest.raises(ValueError) as e:
        validate_landmark_sharding(mesh24, 4)            # maintenance fails
    assert "maintenance grouping" in str(e.value)
    assert "query grouping" not in str(e.value)
    with pytest.raises(ValueError) as e:
        validate_landmark_sharding(mesh24, 6)            # both fail
    assert "maintenance grouping" in str(e.value)
    assert "query grouping" in str(e.value)
