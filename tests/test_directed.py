"""Directed-graph BatchHL (paper §6): both labelling planes vs the directed
oracle, batch updates, and exact directed queries."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests only; optional dep
pytestmark = pytest.mark.slow  # property tests: full CI job only
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.graphs.coo import make_batch, INF_D
from repro.core import ref
from repro.core.directed import (from_arcs, apply_batch_directed,
                                 build_directed_labelling,
                                 batchhl_update_directed, directed_query)

SETTINGS = dict(deadline=None, max_examples=15,
                suppress_health_check=[HealthCheck.too_slow])


def _random_digraph(rng, n):
    m = max(n, int(rng.integers(n, 3 * n)))
    arcs = set()
    # weakly-connected backbone
    for v in range(1, n):
        u = int(rng.integers(v))
        arcs.add((u, v) if rng.random() < 0.7 else (v, u))
    while len(arcs) < m:
        u, v = int(rng.integers(n)), int(rng.integers(n))
        if u != v:
            arcs.add((u, v))
    return np.asarray(sorted(arcs), np.int32)


def _adj_out(g):
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    valid = np.asarray(g.valid)
    adj = {v: set() for v in range(g.n)}
    for s, d, ok in zip(src, dst, valid):
        if ok:
            adj[int(s)].add(int(d))
    return adj


def _landmarks(arcs, n, k):
    deg = np.zeros(n)
    for u, v in arcs:
        deg[u] += 1
        deg[v] += 1
    return np.argsort(-deg, kind="stable")[:k].astype(np.int32)


def _check_plane(lab_plane, adj_out, n, landmarks):
    od, oh, ohw, omask = ref.minimal_labelling_directed(
        adj_out, n, list(landmarks))
    jd = np.asarray(lab_plane.dist)
    jh = np.asarray(lab_plane.hub)
    jm = np.asarray(lab_plane.label_mask())
    for i in range(len(landmarks)):
        for v in range(n):
            want = od[i][v] if od[i][v] != ref.INF else int(INF_D)
            assert jd[i, v] == want, (i, v, jd[i, v], want)
            if od[i][v] != ref.INF:
                assert bool(jh[i, v]) == oh[i][v], (i, v)
            assert bool(jm[i, v]) == omask[i][v], (i, v)


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 32))
def test_directed_construction_matches_oracle(seed, n):
    rng = np.random.default_rng(seed)
    arcs = _random_digraph(rng, n)
    g = from_arcs(n, arcs, arcs.shape[0] + 16)
    landmarks = _landmarks(arcs, n, 3)
    lab = build_directed_labelling(g, jnp.asarray(landmarks))
    adj_out = _adj_out(g)
    _check_plane(lab.fwd, adj_out, n, landmarks)
    _check_plane(lab.bwd, ref.reverse_adj(adj_out, n), n, landmarks)


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 28),
       n_ins=st.integers(0, 4), n_del=st.integers(0, 4))
def test_directed_batch_update_and_queries(seed, n, n_ins, n_del):
    rng = np.random.default_rng(seed)
    arcs = _random_digraph(rng, n)
    g = from_arcs(n, arcs, arcs.shape[0] + 2 * (n_ins + 1))
    landmarks = _landmarks(arcs, n, 3)
    lab = build_directed_labelling(g, jnp.asarray(landmarks))

    existing = {(int(u), int(v)) for u, v in arcs}
    ups = []
    if n_del:
        picks = rng.choice(len(arcs), size=min(n_del, len(arcs)),
                           replace=False)
        ups += [(int(arcs[i, 0]), int(arcs[i, 1]), True) for i in picks]
    tries = 0
    while sum(1 for x in ups if not x[2]) < n_ins and tries < 200:
        tries += 1
        u, v = int(rng.integers(n)), int(rng.integers(n))
        if u != v and (u, v) not in existing:
            existing.add((u, v))
            ups.append((u, v, False))
    batch = make_batch(ups, pad_to=max(len(ups), 1))
    if not ups:
        batch = make_batch([(0, 1, False)], pad_to=1)
        batch = batch.__class__(batch.src, batch.dst, batch.is_del,
                                jnp.zeros_like(batch.valid))

    g2, lab2, _ = batchhl_update_directed(g, batch, lab)
    adj2 = ref.apply_updates_directed(_adj_out(g), ups)
    assert _adj_out(g2) == adj2
    _check_plane(lab2.fwd, adj2, n, landmarks)
    _check_plane(lab2.bwd, ref.reverse_adj(adj2, n), n, landmarks)

    qs = rng.integers(0, n, 12).astype(np.int32)
    qt = rng.integers(0, n, 12).astype(np.int32)
    got = np.asarray(directed_query(g2, lab2, jnp.asarray(qs),
                                    jnp.asarray(qt)))
    for k in range(12):
        want = ref.bfs_dist_directed(adj2, n, int(qs[k]))[int(qt[k])]
        want = 0 if qs[k] == qt[k] else want
        want = int(INF_D) if want == ref.INF else want
        assert got[k] == want, (qs[k], qt[k], got[k], want)
