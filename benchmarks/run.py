"""Benchmark harness: one module per paper table/figure + the tick trajectory.

Prints ``name,us_per_call,derived`` CSV rows. Modules:
  table3_update_time   — Table 3 (BHL⁺/BHL/BHLˢ/UHL⁺ update time)
  table4_construction  — Table 4 (construction, query time, label size)
  table5_affected      — Table 5 + Fig. 2 (affected-vertex counts)
  table6_directed      — Table 6 (directed graphs, two-plane BatchHL)
  fig6_batch_sizes     — Fig. 6 (amortized total time vs batch size)
  fig7_landmarks       — Figs. 7/8 (update/query time vs landmarks)
  ticks                — serving-tick latency per backend × mesh, plus
                         the serve-loop trajectory (open-loop query
                         p50/p95/p99 + staleness, sync vs pipeline)

``--fast`` trims datasets for CI-ish runs; default runs everything.
``--preset quick`` runs only the `ticks` module at CI size — the bench
CI job's configuration. ``--json PATH`` additionally persists every
emitted row in the bench-trajectory format (schema ``repro-bench/v1``:
``{"schema", "jax", "device_count", "rows": [{name, us_per_call,
derived}]}``) consumed by `benchmarks/compare.py` and committed as
`benchmarks/baseline.json`.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _rows_to_json(rows: list[str]) -> list[dict]:
    out = []
    for row in rows:
        name, us, derived = row.split(",", 2)
        out.append({"name": name, "us_per_call": float(us),
                    "derived": derived})
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated module names")
    ap.add_argument("--preset", default=None, choices=("quick",),
                    help="quick = the CI bench job: ticks module only, "
                         "small dataset, both backends, both meshes")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all emitted rows as bench-trajectory JSON")
    args = ap.parse_args()

    from benchmarks import (table3_update_time, table4_construction,
                            table5_affected, table6_directed,
                            fig6_batch_sizes, fig7_landmarks, ticks)
    modules = {
        "table3": table3_update_time,
        "table4": table4_construction,
        "table5": table5_affected,
        "table6": table6_directed,
        "fig6": fig6_batch_sizes,
        "fig7": fig7_landmarks,
        "ticks": ticks,
    }
    if args.preset and args.only:
        ap.error("--preset and --only are mutually exclusive")
    if args.preset == "quick":
        picked = ["ticks"]
    else:
        picked = (args.only.split(",") if args.only else list(modules))
    print("name,us_per_call,derived")
    t0 = time.time()
    all_rows: list[str] = []
    for name in picked:
        mod = modules[name]
        try:
            if name == "ticks" and (args.preset == "quick" or args.fast):
                # 6 ticks → 4 steady-state samples behind the 2 warmup
                # (compile + reshard-retrace) ticks the median drops.
                out = mod.run(datasets=("ba_2k",), ticks=6, batch_size=64,
                              queries=128)
            elif args.fast and name in ("table3", "table4"):
                out = mod.run(datasets=("ba_2k",))
            else:
                out = mod.run()
            all_rows += out
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", file=sys.stderr)
            raise
    print(f"# {len(all_rows)} rows in {time.time() - t0:.1f}s",
          file=sys.stderr)
    if args.json:
        import jax
        payload = {"schema": "repro-bench/v1", "jax": jax.__version__,
                   "device_count": len(jax.devices()),
                   "rows": _rows_to_json(all_rows)}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
