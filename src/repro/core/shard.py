"""Mesh-sharded BatchHL: construction, batch update, and queries under
`shard_map` (DESIGN.md §4).

The paper's §6 parallelism is landmark-plane parallelism: every search,
repair, and construction fixpoint is independent per landmark plane, and
Farhan et al.'s incremental follow-up confirms the independence survives
updates. The unsharded code realizes it as a single-device `vmap` over the
R axis; this module lifts the same per-plane functions onto a device mesh
(`launch/mesh.py`: `data` × `model`):

* **Maintenance** (``shard_build_labelling`` / ``shard_batchhl_update``):
  landmark planes are sharded over the ``model`` axis and — since no
  queries run mid-update — over the idle ``data`` axis too (the combined
  ``("model", "data")`` spec). Each shard runs the stock plane-slice
  fixpoints (`construct_key2_planes`, `search_*_planes`, `repair_planes`)
  on its local planes with the graph replicated: all-local, zero
  cross-shard traffic inside the wave loops. Only the highway rows leave
  the shard, assembled row-sharded by the out-spec (the "highway gather"
  happens lazily as an all-gather when a consumer needs it replicated).

* **Queries** (``shard_batched_query``): landmark planes over ``model``,
  the query batch over ``data``. The Eq.-3 min-contraction reduces over
  the sharded landmark axes through collectives (one `all_gather` of the
  target labels + one `pmin`); the bounded BiBFS runs all-local per query
  shard. Query batches are padded to the data-axis size and sliced back.

* **Cross-plane reductions** (``affected_vertices``): the per-plane `aff`
  planes OR-merge into one affected-vertex mask through a `pmax`.

Bit-parity: per-plane values are exact int32 fixpoints independent of
iteration count, and min/OR reductions are associative — sharded outputs
are bit-identical to the unsharded `vmap` path on any mesh shape
(`tests/test_shard.py` pins it on 1-device and forced-8-device meshes).

Sweep backends: both engine backends run *inside* the shard bodies. The
`RelaxPlan` rides into every `shard_map` as an ordinary replicated
argument (in_spec `P()` over its pytree leaves — the plan pytree may be
None, the tile-less jnp plan, or a full Pallas tiling), so each device
launches the tiled `edge_relax` kernel on its local planes; the
shard-aware tiling (`kernels/edge_relax`, leading vertex-shard axis on
`BlockedGraph`) is bit-identical for every shard count, and the tiling is
prepared once by the host-side `RelaxEngine` and reused by sharded and
unsharded call-sites alike (DESIGN.md §3–§4). With `use_kernel=True` the
query bound runs the `minplus` kernel per shard on its local highway rows
([P, R] rectangular contraction) and a `pmin` over the model axis
finishes the reduction — no [R, R] plane product is materialized.

Requirements: R must divide evenly over the plane-sharding axes (data ×
model for maintenance, model for queries). Query batches are padded
automatically; landmark counts are validated with a clear error.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.graphs.coo import (Graph, BatchUpdate, INF_D, apply_batch,
                              resolve_seed_weights)
from repro.core.batch import (check_labelling_width, frontier_wave,
                              repair_base, repair_base_frontier,
                              repair_merge, repair_planes,
                              repair_step, repair_step_rows,
                              search_basic_planes,
                              search_basic_seed, search_basic_step,
                              search_improved_planes, search_improved_seed,
                              search_improved_step, search_step_rows)
from repro.core.construct import construct_key2_planes
from repro.core.engine import RelaxPlan
from repro.core.labelling import (HighwayLabelling, INF_KEY2, key2_dist,
                                  key2_hub, key2_make, per_plane_hub_mask)
from repro.core.query import bounded_bibfs, effective_label_planes

#: Plane-sharding spec during maintenance: landmark planes over the whole
#: grid (`model` major, `data` minor — the data axis is idle while the
#: labelling is being rewritten, so it contributes landmark parallelism).
MAINT_AXES = ("model", "data")


def _check_planes(r: int, size: int, what: str) -> None:
    if r % size:
        raise ValueError(
            f"landmark count {r} must be divisible by the {what} "
            f"sharding size {size}; pick R as a multiple (or a smaller "
            f"--shards / mesh)")


def _maint_size(mesh) -> int:
    return mesh.shape["model"] * mesh.shape["data"]


def validate_landmark_sharding(mesh, r: int) -> None:
    """Pre-flight check of R against *both* plane groupings of a mesh.

    Maintenance shards landmark planes over data·model (the idle data
    axis donates its parallelism); queries regroup them over model only.
    Each failing grouping is named explicitly — `R % n_devices` alone
    can't tell a caller which phase's regrouping broke, and keeps working
    silently if the groupings ever diverge.
    """
    data, model = mesh.shape["data"], mesh.shape["model"]
    failing = []
    if r % (data * model):
        failing.append(f"maintenance grouping data×model = "
                       f"{data}×{model} = {data * model}")
    if r % model:
        failing.append(f"query grouping model = {model}")
    if failing:
        raise ValueError(
            f"landmark count R={r} must be divisible by every plane "
            f"grouping of the mesh; failing: {'; '.join(failing)} — pick "
            f"R as a multiple, or a smaller mesh / --shards")


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("mesh", "max_iters"))
def shard_build_labelling(mesh, g: Graph, landmarks: jax.Array,
                          max_iters: int | None = None,
                          plan: RelaxPlan | None = None) -> HighwayLabelling:
    """`build_labelling` under shard_map; bit-identical outputs.

    Returns a labelling whose dist/hub planes are sharded over
    ``("model", "data")`` on the R axis and whose highway is row-sharded;
    consumers reshard transparently. `plan` (replicated into every shard)
    selects the sweep backend — Pallas plans launch the tiled kernel on
    each shard's local planes.
    """
    _check_planes(landmarks.shape[0], _maint_size(mesh), "maintenance")

    def body(g, own, landmarks_full, plan):
        key2 = construct_key2_planes(g, own, landmarks_full, max_iters, plan)
        dist = jnp.minimum(key2_dist(key2), INF_D)
        hub = key2_hub(key2) & (dist < INF_D)
        highway = dist[:, landmarks_full]    # local rows [P, R]
        return dist, hub, highway

    rv = P(MAINT_AXES, None)
    dist, hub, highway = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(MAINT_AXES), P(), P()),
        out_specs=(rv, rv, rv),
        # jax 0.4.37 has no replication rule for while_loop (the fixpoint
        # sweeps); every output is fully plane-sharded anyway.
        check_rep=False)(g, landmarks, landmarks, plan)
    return HighwayLabelling(landmarks.astype(jnp.int32), dist, hub, highway)


# ---------------------------------------------------------------------------
# Batch update
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("mesh", "improved"))
def shard_batchhl_update(mesh, g_old: Graph, batch: BatchUpdate,
                         labelling: HighwayLabelling, improved: bool = True,
                         plan: RelaxPlan | None = None,
                         g_new: Graph | None = None
                         ) -> tuple[Graph, HighwayLabelling, jax.Array]:
    """`batchhl_update` under shard_map; bit-identical (G', Γ', aff).

    Per-plane search + repair run all-local on each shard's plane slice;
    the batch, both graph snapshots, and the plan are replicated. aff and
    the new planes come back sharded over ``("model", "data")`` on the R
    axis. Like `batchhl_update`, a Pallas `plan` must be prepared from the
    *post-update* snapshot; callers that already materialized it (for that
    prepare) can pass it as `g_new` to skip the recompute.
    """
    _check_planes(labelling.num_landmarks, _maint_size(mesh), "maintenance")
    # Trace-time growth guard: a grown graph with un-grown planes would
    # otherwise die as a GSPMD shape error inside the shard_map body.
    check_labelling_width(g_old, labelling.dist)
    if g_new is None:
        g_new = apply_batch(g_old, batch)
    # Same seed-weight contract as the unsharded batchhl_update: seeds
    # cross deletion/re-weight edges at their pre-update weight, resolved
    # against g_old; apply_batch above took the original batch.
    batch = resolve_seed_weights(g_old, batch)

    def body(g_new, batch, dist, hub, own, landmarks_full, plan):
        hub_mask = per_plane_hub_mask(landmarks_full, own, g_new.n)
        if improved:
            aff = search_improved_planes(g_new, batch, dist, hub, hub_mask,
                                         plan)
        else:
            aff = search_basic_planes(g_new, batch, dist, plan)
        new_key2 = repair_planes(g_new, aff, key2_make(dist, hub), hub_mask,
                                 plan)
        ndist = jnp.minimum(key2_dist(new_key2), INF_D)
        nhub = key2_hub(new_key2) & (ndist < INF_D)
        highway = ndist[:, landmarks_full]   # local rows [P, R]
        return ndist, nhub, highway, aff

    rv = P(MAINT_AXES, None)
    ndist, nhub, highway, aff = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), rv, rv, P(MAINT_AXES), P(), P()),
        out_specs=(rv, rv, rv, rv),
        # No replication rule for while_loop on this jax pin; outputs are
        # fully plane-sharded anyway.
        check_rep=False)(
            g_new, batch, labelling.dist, labelling.hub,
            labelling.landmarks, labelling.landmarks, plan)
    new_labelling = HighwayLabelling(labelling.landmarks, ndist, nhub,
                                     highway)
    return g_new, new_labelling, aff


@partial(jax.jit, static_argnames=("mesh",))
def affected_vertices(mesh, aff: jax.Array) -> jax.Array:
    """OR-merge the per-plane affected sets into one bool[V] vertex mask.

    The cross-plane reduction of DESIGN.md §4: each shard ORs its local
    planes, then a `pmax` over the plane-sharding axes merges the shards.
    """
    def body(aff_loc):
        any_loc = jnp.any(aff_loc, axis=0).astype(jnp.int32)
        return jax.lax.pmax(any_loc, MAINT_AXES) > 0

    return shard_map(body, mesh=mesh,
                     in_specs=(P(MAINT_AXES, None),),
                     out_specs=P(None))(aff)


# ---------------------------------------------------------------------------
# Bounded update chunks (the serving pipeline's mesh path, DESIGN.md §5)
# ---------------------------------------------------------------------------
#
# `core/snapshot.pipelined_update` runs the batch update as bounded
# dispatches so query microbatches interleave on the device queue. These
# are the mesh twins of the unsharded chunk jits in `core/snapshot.py`:
# the same seed/step functions from `core/batch.py`, under shard_map on
# the maintenance plane grouping (landmark planes over ("model", "data")),
# with the graph, batch, and plan replicated. The per-chunk `changed`
# flag is the one cross-shard reduction (a pmax OR-merge); everything
# else is all-local, exactly like the monolithic maintenance bodies.

@partial(jax.jit, static_argnames=("mesh", "improved"))
def shard_search_seed(mesh, g_new: Graph, batch: BatchUpdate,
                      dist: jax.Array, hub: jax.Array, landmarks: jax.Array,
                      improved: bool = True):
    """Mesh twin of `snapshot.search_seed`; outputs plane-sharded rv."""
    _check_planes(landmarks.shape[0], _maint_size(mesh), "maintenance")
    check_labelling_width(g_new, dist)

    def body(g_new, batch, dist, hub, own, landmarks_full):
        hub_mask = per_plane_hub_mask(landmarks_full, own, g_new.n)
        if improved:
            seed, seeded, beta = search_improved_seed(g_new, batch, dist,
                                                      hub, hub_mask)
            return seed, seeded, beta, hub_mask
        seed, seeded = search_basic_seed(g_new, batch, dist)
        return seed, seeded, dist, hub_mask

    rv = P(MAINT_AXES, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), rv, rv, P(MAINT_AXES), P()),
        out_specs=(rv, rv, rv, rv),
        check_rep=False)(g_new, batch, dist, hub, landmarks, landmarks)


@partial(jax.jit, static_argnames=("mesh", "improved", "sweeps"))
def shard_search_chunk(mesh, g_new: Graph, best: jax.Array, seed: jax.Array,
                       bound: jax.Array, hub_mask: jax.Array,
                       plan: RelaxPlan | None, improved: bool = True,
                       sweeps: int = 1):
    """Mesh twin of `snapshot.search_chunk` → (best', changed scalar)."""

    def body(g_new, best, seed, bound, hub_mask, plan):
        cur = best
        for _ in range(sweeps):
            if improved:
                cur = search_improved_step(plan, g_new, cur, seed, bound,
                                           hub_mask)
            else:
                cur = search_basic_step(plan, g_new, cur, seed, bound)
        changed = jax.lax.pmax(
            jnp.any(cur != best).astype(jnp.int32), MAINT_AXES)
        return cur, changed > 0

    rv = P(MAINT_AXES, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), rv, rv, rv, rv, P()),
        out_specs=(rv, P()),
        check_rep=False)(g_new, best, seed, bound, hub_mask, plan)


@partial(jax.jit, static_argnames=("mesh",))
def shard_repair_start(mesh, g_new: Graph, aff: jax.Array, dist: jax.Array,
                       hub: jax.Array, hub_mask: jax.Array,
                       plan: RelaxPlan | None) -> jax.Array:
    """Mesh twin of `snapshot.repair_start` (Algo-4 boundary seeding)."""

    def body(g_new, aff, dist, hub, hub_mask, plan):
        return repair_base(plan, g_new, aff, key2_make(dist, hub), hub_mask)

    rv = P(MAINT_AXES, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), rv, rv, rv, rv, P()),
        out_specs=rv,
        check_rep=False)(g_new, aff, dist, hub, hub_mask, plan)


@partial(jax.jit, static_argnames=("mesh", "sweeps"))
def shard_repair_chunk(mesh, g_new: Graph, cur: jax.Array, aff: jax.Array,
                       hub_mask: jax.Array, plan: RelaxPlan | None,
                       sweeps: int = 1):
    """Mesh twin of `snapshot.repair_chunk` → (cur', changed scalar)."""

    def body(g_new, cur, aff, hub_mask, plan):
        out = cur
        for _ in range(sweeps):
            out = repair_step(plan, g_new, out, aff, hub_mask)
        changed = jax.lax.pmax(
            jnp.any(out != cur).astype(jnp.int32), MAINT_AXES)
        return out, changed > 0

    rv = P(MAINT_AXES, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), rv, rv, rv, P()),
        out_specs=(rv, P()),
        check_rep=False)(g_new, cur, aff, hub_mask, plan)


# --- fused chunk twins (seed + K sweeps in one dispatch; donated planes) ---
#
# Mesh versions of `snapshot.fused_*`: same fusion boundaries, same
# donation contract (the labelling plane argument is donated and must be
# rebound by the caller after every chunk), with the per-chunk `changed`
# flag pmax-merged across the maintenance grouping like the unfused
# chunk twins above.

@partial(jax.jit, static_argnames=("mesh", "improved", "sweeps"))
def shard_fused_search_start(mesh, g_new: Graph, batch: BatchUpdate,
                             dist: jax.Array, hub: jax.Array,
                             landmarks: jax.Array, plan: RelaxPlan | None,
                             improved: bool = True, sweeps: int = 1):
    """Mesh twin of `snapshot.fused_search_start` →
    (best, seed, seeded, bound, hub_mask, changed)."""
    _check_planes(landmarks.shape[0], _maint_size(mesh), "maintenance")
    check_labelling_width(g_new, dist)

    def body(g_new, batch, dist, hub, own, landmarks_full, plan):
        hub_mask = per_plane_hub_mask(landmarks_full, own, g_new.n)
        if improved:
            seed, seeded, bound = search_improved_seed(g_new, batch, dist,
                                                       hub, hub_mask)
        else:
            seed, seeded = search_basic_seed(g_new, batch, dist)
            bound = dist
        best = seed
        for _ in range(sweeps):
            if improved:
                best = search_improved_step(plan, g_new, best, seed, bound,
                                            hub_mask)
            else:
                best = search_basic_step(plan, g_new, best, seed, bound)
        changed = jax.lax.pmax(
            jnp.any(best != seed).astype(jnp.int32), MAINT_AXES)
        return best, seed, seeded, bound, hub_mask, changed > 0

    rv = P(MAINT_AXES, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), rv, rv, P(MAINT_AXES), P(), P()),
        out_specs=(rv, rv, rv, rv, rv, P()),
        check_rep=False)(g_new, batch, dist, hub, landmarks, landmarks,
                         plan)


@partial(jax.jit, static_argnames=("mesh", "improved", "sweeps"),
         donate_argnums=(2,))
def shard_fused_search_chunk(mesh, g_new: Graph, best: jax.Array,
                             seed: jax.Array, bound: jax.Array,
                             hub_mask: jax.Array, plan: RelaxPlan | None,
                             improved: bool = True, sweeps: int = 1):
    """`shard_search_chunk` with the labelling plane donated."""

    def body(g_new, best, seed, bound, hub_mask, plan):
        cur = best
        for _ in range(sweeps):
            if improved:
                cur = search_improved_step(plan, g_new, cur, seed, bound,
                                           hub_mask)
            else:
                cur = search_basic_step(plan, g_new, cur, seed, bound)
        changed = jax.lax.pmax(
            jnp.any(cur != best).astype(jnp.int32), MAINT_AXES)
        return cur, changed > 0

    rv = P(MAINT_AXES, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), rv, rv, rv, rv, P()),
        out_specs=(rv, P()),
        check_rep=False)(g_new, best, seed, bound, hub_mask, plan)


@partial(jax.jit, static_argnames=("mesh", "sweeps"))
def shard_fused_repair_start_chunk(mesh, g_new: Graph, aff: jax.Array,
                                   dist: jax.Array, hub: jax.Array,
                                   hub_mask: jax.Array,
                                   plan: RelaxPlan | None, sweeps: int = 1):
    """Mesh twin of `snapshot.fused_repair_start_chunk` → (cur, changed)."""

    def body(g_new, aff, dist, hub, hub_mask, plan):
        cur0 = repair_base(plan, g_new, aff, key2_make(dist, hub), hub_mask)
        cur = cur0
        for _ in range(sweeps):
            cur = repair_step(plan, g_new, cur, aff, hub_mask)
        changed = jax.lax.pmax(
            jnp.any(cur != cur0).astype(jnp.int32), MAINT_AXES)
        return cur, changed > 0

    rv = P(MAINT_AXES, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), rv, rv, rv, rv, P()),
        out_specs=(rv, P()),
        check_rep=False)(g_new, aff, dist, hub, hub_mask, plan)


@partial(jax.jit, static_argnames=("mesh", "sweeps"), donate_argnums=(2,))
def shard_fused_repair_chunk(mesh, g_new: Graph, cur: jax.Array,
                             aff: jax.Array, hub_mask: jax.Array,
                             plan: RelaxPlan | None, sweeps: int = 1):
    """`shard_repair_chunk` with the key2 plane donated."""

    def body(g_new, cur, aff, hub_mask, plan):
        out = cur
        for _ in range(sweeps):
            out = repair_step(plan, g_new, out, aff, hub_mask)
        changed = jax.lax.pmax(
            jnp.any(out != cur).astype(jnp.int32), MAINT_AXES)
        return out, changed > 0

    rv = P(MAINT_AXES, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), rv, rv, rv, P()),
        out_specs=(rv, P()),
        check_rep=False)(g_new, cur, aff, hub_mask, plan)


# --- frontier chunk twins (change propagation, DESIGN.md §10) --------------
#
# Mesh versions of `snapshot.*_frontier`: the per-plane changed-block
# bitmap `front` [P, NBf] shards over the maintenance grouping exactly
# like the labelling planes (rv), so each device propagates and relaxes
# the frontier of *its own* plane slice — the masked/full density branch
# is taken per device, against its local frontier (a tighter mask than a
# global one, and still exact per plane). The convergence flag is the
# usual pmax OR-merge of "is my local frontier non-empty".

def _shard_search_wave_fns(plan, g_new, seed, bound, hub_mask, improved):
    if improved:
        return (lambda b: search_improved_step(plan, g_new, b, seed, bound,
                                               hub_mask),
                lambda b, rows_g: search_step_rows(rows_g, b, bound,
                                                   hub_mask, improved=True))
    return (lambda b: search_basic_step(plan, g_new, b, seed, bound),
            lambda b, rows_g: search_step_rows(rows_g, b, bound, None,
                                               improved=False))


@partial(jax.jit, static_argnames=("mesh", "improved", "sweeps"))
def shard_search_chunk_frontier(mesh, g_new: Graph, best: jax.Array,
                                front: jax.Array, seed: jax.Array,
                                bound: jax.Array, hub_mask: jax.Array,
                                plan: RelaxPlan, improved: bool = True,
                                sweeps: int = 1):
    """Mesh twin of `snapshot.search_chunk_frontier` →
    (best', front', changed scalar)."""

    def body(g_new, best, front, seed, bound, hub_mask, plan):
        full, masked = _shard_search_wave_fns(plan, g_new, seed, bound,
                                              hub_mask, improved)
        cur = best
        for _ in range(sweeps):
            cur, front = frontier_wave(plan, g_new, full, masked, cur, front)
        changed = jax.lax.pmax(
            jnp.any(front).astype(jnp.int32), MAINT_AXES)
        return cur, front, changed > 0

    rv = P(MAINT_AXES, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), rv, rv, rv, rv, rv, P()),
        out_specs=(rv, rv, P()),
        check_rep=False)(g_new, best, front, seed, bound, hub_mask, plan)


@partial(jax.jit, static_argnames=("mesh",))
def shard_repair_start_frontier(mesh, g_new: Graph, aff: jax.Array,
                                dist: jax.Array, hub: jax.Array,
                                hub_mask: jax.Array, plan: RelaxPlan):
    """Mesh twin of `snapshot.repair_start_frontier` → (base, front)."""

    def body(g_new, aff, dist, hub, hub_mask, plan):
        base = repair_base_frontier(plan, g_new, aff, key2_make(dist, hub),
                                    hub_mask)
        return base, plan.frontier.changed_blocks(base < INF_KEY2)

    rv = P(MAINT_AXES, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), rv, rv, rv, rv, P()),
        out_specs=(rv, rv),
        check_rep=False)(g_new, aff, dist, hub, hub_mask, plan)


@partial(jax.jit, static_argnames=("mesh", "sweeps"))
def shard_repair_chunk_frontier(mesh, g_new: Graph, cur: jax.Array,
                                front: jax.Array, aff: jax.Array,
                                hub_mask: jax.Array, plan: RelaxPlan,
                                sweeps: int = 1):
    """Mesh twin of `snapshot.repair_chunk_frontier` →
    (cur', front', changed scalar)."""

    def body(g_new, cur, front, aff, hub_mask, plan):
        full = lambda c: repair_step(plan, g_new, c, aff, hub_mask)
        masked = lambda c, rows_g: repair_step_rows(rows_g, c, aff, hub_mask)
        out = cur
        for _ in range(sweeps):
            out, front = frontier_wave(plan, g_new, full, masked, out, front)
        changed = jax.lax.pmax(
            jnp.any(front).astype(jnp.int32), MAINT_AXES)
        return out, front, changed > 0

    rv = P(MAINT_AXES, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), rv, rv, rv, rv, P()),
        out_specs=(rv, rv, P()),
        check_rep=False)(g_new, cur, front, aff, hub_mask, plan)


@partial(jax.jit, static_argnames=("mesh", "improved", "sweeps"))
def shard_fused_search_start_frontier(mesh, g_new: Graph,
                                      batch: BatchUpdate, dist: jax.Array,
                                      hub: jax.Array, landmarks: jax.Array,
                                      plan: RelaxPlan, improved: bool = True,
                                      sweeps: int = 1):
    """Mesh twin of `snapshot.fused_search_start_frontier` →
    (best, front, seed, seeded, bound, hub_mask, changed)."""
    _check_planes(landmarks.shape[0], _maint_size(mesh), "maintenance")
    check_labelling_width(g_new, dist)

    def body(g_new, batch, dist, hub, own, landmarks_full, plan):
        hub_mask = per_plane_hub_mask(landmarks_full, own, g_new.n)
        if improved:
            seed, seeded, bound = search_improved_seed(g_new, batch, dist,
                                                       hub, hub_mask)
        else:
            seed, seeded = search_basic_seed(g_new, batch, dist)
            bound = dist
        front = plan.frontier.changed_blocks(seeded)
        full, masked = _shard_search_wave_fns(plan, g_new, seed, bound,
                                              hub_mask, improved)
        best = seed
        for _ in range(sweeps):
            best, front = frontier_wave(plan, g_new, full, masked, best,
                                        front)
        changed = jax.lax.pmax(
            jnp.any(front).astype(jnp.int32), MAINT_AXES)
        return best, front, seed, seeded, bound, hub_mask, changed > 0

    rv = P(MAINT_AXES, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(), rv, rv, P(MAINT_AXES), P(), P()),
        out_specs=(rv, rv, rv, rv, rv, rv, P()),
        check_rep=False)(g_new, batch, dist, hub, landmarks, landmarks,
                         plan)


@partial(jax.jit, static_argnames=("mesh", "improved", "sweeps"),
         donate_argnums=(2,))
def shard_fused_search_chunk_frontier(mesh, g_new: Graph, best: jax.Array,
                                      front: jax.Array, seed: jax.Array,
                                      bound: jax.Array, hub_mask: jax.Array,
                                      plan: RelaxPlan, improved: bool = True,
                                      sweeps: int = 1):
    """`shard_search_chunk_frontier` with the labelling plane donated."""

    def body(g_new, best, front, seed, bound, hub_mask, plan):
        full, masked = _shard_search_wave_fns(plan, g_new, seed, bound,
                                              hub_mask, improved)
        cur = best
        for _ in range(sweeps):
            cur, front = frontier_wave(plan, g_new, full, masked, cur, front)
        changed = jax.lax.pmax(
            jnp.any(front).astype(jnp.int32), MAINT_AXES)
        return cur, front, changed > 0

    rv = P(MAINT_AXES, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), rv, rv, rv, rv, rv, P()),
        out_specs=(rv, rv, P()),
        check_rep=False)(g_new, best, front, seed, bound, hub_mask, plan)


@partial(jax.jit, static_argnames=("mesh", "sweeps"))
def shard_fused_repair_start_chunk_frontier(mesh, g_new: Graph,
                                            aff: jax.Array, dist: jax.Array,
                                            hub: jax.Array,
                                            hub_mask: jax.Array,
                                            plan: RelaxPlan,
                                            sweeps: int = 1):
    """Mesh twin of `snapshot.fused_repair_start_chunk_frontier` →
    (cur, front, changed)."""

    def body(g_new, aff, dist, hub, hub_mask, plan):
        cur = repair_base_frontier(plan, g_new, aff, key2_make(dist, hub),
                                   hub_mask)
        front = plan.frontier.changed_blocks(cur < INF_KEY2)
        full = lambda c: repair_step(plan, g_new, c, aff, hub_mask)
        masked = lambda c, rows_g: repair_step_rows(rows_g, c, aff, hub_mask)
        for _ in range(sweeps):
            cur, front = frontier_wave(plan, g_new, full, masked, cur, front)
        changed = jax.lax.pmax(
            jnp.any(front).astype(jnp.int32), MAINT_AXES)
        return cur, front, changed > 0

    rv = P(MAINT_AXES, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), rv, rv, rv, rv, P()),
        out_specs=(rv, rv, P()),
        check_rep=False)(g_new, aff, dist, hub, hub_mask, plan)


@partial(jax.jit, static_argnames=("mesh", "sweeps"), donate_argnums=(2,))
def shard_fused_repair_chunk_frontier(mesh, g_new: Graph, cur: jax.Array,
                                      front: jax.Array, aff: jax.Array,
                                      hub_mask: jax.Array, plan: RelaxPlan,
                                      sweeps: int = 1):
    """`shard_repair_chunk_frontier` with the key2 plane donated."""

    def body(g_new, cur, front, aff, hub_mask, plan):
        full = lambda c: repair_step(plan, g_new, c, aff, hub_mask)
        masked = lambda c, rows_g: repair_step_rows(rows_g, c, aff, hub_mask)
        out = cur
        for _ in range(sweeps):
            out, front = frontier_wave(plan, g_new, full, masked, out, front)
        changed = jax.lax.pmax(
            jnp.any(front).astype(jnp.int32), MAINT_AXES)
        return out, front, changed > 0

    rv = P(MAINT_AXES, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), rv, rv, rv, rv, P()),
        out_specs=(rv, rv, P()),
        check_rep=False)(g_new, cur, front, aff, hub_mask, plan)


@partial(jax.jit, static_argnames=("mesh",))
def shard_update_finish(mesh, aff: jax.Array, settled: jax.Array,
                        dist: jax.Array, hub: jax.Array,
                        landmarks: jax.Array) -> HighwayLabelling:
    """Mesh twin of `snapshot.update_finish`; labelling comes back
    plane-sharded rv with row-sharded highway, like the monolithic
    `shard_batchhl_update`."""

    def body(aff, settled, dist, hub, landmarks_full):
        new_key2 = repair_merge(aff, settled, key2_make(dist, hub))
        ndist = jnp.minimum(key2_dist(new_key2), INF_D)
        nhub = key2_hub(new_key2) & (ndist < INF_D)
        highway = ndist[:, landmarks_full]   # local rows [P, R]
        return ndist, nhub, highway

    rv = P(MAINT_AXES, None)
    ndist, nhub, highway = shard_map(
        body, mesh=mesh,
        in_specs=(rv, rv, rv, rv, P()),
        out_specs=(rv, rv, rv),
        check_rep=False)(aff, settled, dist, hub, landmarks)
    return HighwayLabelling(landmarks.astype(jnp.int32), ndist, nhub,
                            highway)


# ---------------------------------------------------------------------------
# Queries
# ---------------------------------------------------------------------------

def shard_batched_query(mesh, g: Graph, labelling: HighwayLabelling,
                        s: jax.Array, t: jax.Array, max_steps: int = 64,
                        use_kernel: bool = False,
                        plan: RelaxPlan | None = None) -> jax.Array:
    """`batched_query` under shard_map; bit-identical exact distances.

    Landmark planes shard over ``model``; the query batch shards over
    ``data`` (padded to a multiple of the data-axis size, sliced back).
    The Eq.-3 upper bound reduces over the sharded landmark axis with one
    `all_gather` (target labels) + one `pmin`; the BiBFS expands each
    query shard all-local against the replicated graph. Within a data
    shard the BiBFS batch composition differs from the unsharded run, but
    the returned min(d_sparse, d⊤) is composition-independent: BFS levels
    are exact, so d_sparse is exact whenever it undercuts d⊤ and is
    dominated by d⊤ otherwise.
    """
    # The pad/slice stays *outside* the jitted core: on the pinned jax,
    # GSPMD mis-reshards a concatenate produced inside the same jit as a
    # multi-axis shard_map consuming it with P("data") — lanes interleave
    # across devices. The padded path is locked in by the B=37 sweep over
    # data>1 meshes in `_selftest` below (run as
    # tests/test_shard.py::test_multidevice_parity_selftest).
    b = s.shape[0]
    pad = (-b) % mesh.shape["data"]
    if pad:
        s = jnp.concatenate([s, jnp.zeros((pad,), s.dtype)])
        t = jnp.concatenate([t, jnp.zeros((pad,), t.dtype)])
    out = _shard_query_core(mesh, g, labelling, s, t, max_steps, use_kernel,
                            plan)
    return out[:b]


@partial(jax.jit, static_argnames=("mesh", "max_steps", "use_kernel"))
def _shard_query_core(mesh, g: Graph, labelling: HighwayLabelling,
                      s: jax.Array, t: jax.Array, max_steps: int,
                      use_kernel: bool,
                      plan: RelaxPlan | None) -> jax.Array:
    _check_planes(labelling.num_landmarks, mesh.shape["model"], "model")

    def body(g, dist, hub, own, landmarks_full, highway_rows, s, t, plan):
        # Eq. 3 — tropical contraction with the landmark axis sharded:
        # each shard contracts its local highway rows [P, R] against the
        # all-gathered target labels; a pmin over `model` finishes the
        # reduction. No [R, R] plane product is ever materialized.
        vals = effective_label_planes(dist, hub, own, landmarks_full)
        s_lab = jnp.minimum(vals[:, s].T, INF_D)      # [B_loc, P]
        t_lab = jnp.minimum(vals[:, t].T, INF_D)      # [B_loc, P]
        t_all = jax.lax.all_gather(t_lab, "model", axis=1, tiled=True)
        if use_kernel:
            # Per-shard minplus launch on the rectangular [P, R]
            # highway-row slice. Same auto-dispatch as the unsharded
            # query_upper_bound: the Pallas kernel on TPU, the jnp oracle
            # elsewhere — so --use-minplus-kernel costs the same with and
            # without a mesh (tests/test_shard_tiling.py pins the
            # interpret-mode kernel inside shard_map separately).
            from repro.kernels.minplus import ops as minplus_ops
            partial_bound = minplus_ops.minplus_bound(
                s_lab, highway_rows, t_all)
        else:
            # mid[b, j] = min over local i of s_lab[b, i] + H[i, j]
            mid = jnp.min(s_lab[:, :, None] + highway_rows[None, :, :],
                          axis=1)
            partial_bound = jnp.min(mid + t_all, axis=1)  # [B_loc]
        d_top = jnp.minimum(jax.lax.pmin(partial_bound, "model"), INF_D)

        # Bounded BiBFS on the local query shard (replicated over model).
        d_sparse = bounded_bibfs(g, landmarks_full, s, t, d_top, max_steps,
                                 plan)
        out = jnp.minimum(d_sparse, d_top)
        return jnp.where(out >= INF_D, INF_D, out)

    qv = P("model", None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), qv, qv, P("model"), P(), qv, P("data"), P("data"),
                  P()),
        out_specs=P("data"),
        # check_rep can't see through the BiBFS while_loop; replication
        # over `model` holds by construction (all body inputs are either
        # replicated or pmin-merged before the loop).
        check_rep=False)(
            g, labelling.dist, labelling.hub, labelling.landmarks,
            labelling.landmarks, labelling.highway, s, t, plan)


# ---------------------------------------------------------------------------
# Self-test (runnable under a forced multi-device host platform)
# ---------------------------------------------------------------------------

def _selftest() -> None:
    """Sharded-vs-unsharded bit-parity on every host-mesh factorization,
    on both sweep backends (jnp reference and the shard-aware Pallas
    tiling, incl. the per-shard minplus kernel bound).

    Run with a forced device count to exercise real multi-device meshes:

        XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
            PYTHONPATH=src python -m repro.core.shard
    """
    import numpy as np
    from repro.graphs import generators as gen
    from repro.graphs.coo import apply_batch, from_edges, make_batch
    from repro.core.construct import build_labelling, \
        select_landmarks_by_degree
    from repro.core.batch import batchhl_update
    from repro.core.engine import RelaxEngine
    from repro.core.query import batched_query
    from repro.launch.mesh import make_host_mesh

    n_dev = len(jax.devices())
    n, r = 120, 8
    edges = gen.random_connected(n, extra_edges=150, seed=3)
    g = from_edges(n, edges, edges.shape[0] + 64)
    landmarks = select_landmarks_by_degree(g, r)
    ups = gen.random_batch_updates(edges, n, n_ins=6, n_del=6, seed=9)
    batch = make_batch(ups, pad_to=12)
    rng = np.random.default_rng(0)
    qs = jnp.asarray(rng.integers(0, n, 37), jnp.int32)   # odd B → padding
    qt = jnp.asarray(rng.integers(0, n, 37), jnp.int32)

    lab0 = build_labelling(g, landmarks)
    g1, lab1, aff1 = batchhl_update(g, batch, lab0, improved=True)
    d1 = batched_query(g1, lab1, qs, qt)

    # Shard-aware Pallas tiling (2 vertex shards): one plan per snapshot,
    # reused across every mesh factorization below.
    engine = RelaxEngine(backend="pallas", block_v=32, shards=2)
    plan0 = engine.prepare(g)
    g1_host = apply_batch(g, batch)
    engine1 = RelaxEngine(backend="pallas", block_v=32, shards=2)
    plan1 = engine1.prepare(g1_host)

    for model in [m for m in (1, 2, 4, 8) if n_dev % m == 0]:
        mesh = make_host_mesh(model=model)
        for backend, pln0, pln1 in (("jnp", None, None),
                                    ("pallas", plan0, plan1)):
            slab0 = shard_build_labelling(mesh, g, landmarks, plan=pln0)
            for f in ("dist", "hub", "highway"):
                np.testing.assert_array_equal(np.asarray(getattr(slab0, f)),
                                              np.asarray(getattr(lab0, f)))
            sg1, slab1, saff1 = shard_batchhl_update(mesh, g, batch, slab0,
                                                     plan=pln1)
            np.testing.assert_array_equal(np.asarray(saff1),
                                          np.asarray(aff1))
            for f in ("dist", "hub", "highway"):
                np.testing.assert_array_equal(np.asarray(getattr(slab1, f)),
                                              np.asarray(getattr(lab1, f)))
            sd1 = shard_batched_query(mesh, sg1, slab1, qs, qt,
                                      use_kernel=(backend == "pallas"),
                                      plan=pln1)
            np.testing.assert_array_equal(np.asarray(sd1), np.asarray(d1))
            affv = affected_vertices(mesh, saff1)
            np.testing.assert_array_equal(
                np.asarray(affv), np.asarray(jnp.any(aff1, axis=0)))
            print(f"mesh (data={mesh.shape['data']}, model={model}) "
                  f"backend={backend}: construction/update/query "
                  f"bit-parity OK")
    print(f"selftest OK on {n_dev} device(s)")


if __name__ == "__main__":
    _selftest()
