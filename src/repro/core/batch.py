"""BatchHL: batch search (Algorithms 2 & 3) and batch repair (Algorithm 4).

TPU adaptation: the paper's priority-queue best-first searches become
monotone fixpoints of dense edge-relaxation sweeps (see DESIGN.md §2).
Because every expansion step adds exactly one hop, the queue is monotone and
its pop order is immaterial to the final key of each vertex — the sweep
fixpoint equals the queue result. All landmark planes run vmapped in
lockstep (the paper's landmark parallelism, §6) and the vertex axis is
shardable across the mesh `data` axis.

Every sweep routes through the relaxation engine (`core/engine.py`,
DESIGN.md §3): pass a `RelaxPlan` (from `RelaxEngine.prepare`) to run the
tiled Pallas `edge_relax` kernel; the default `plan=None` runs the pure-jnp
segment-min reference — both backends produce identical results.

Variants (paper §7 naming):
  BHL   = basic batch search (Algo 2) + batch repair (Algo 4)
  BHL+  = improved batch search (Algo 3) + batch repair (Algo 4)
  BHL^s = split insert/delete sub-batches (for Fig. 2 comparisons)
  UHL+  = unit-update loop (single-update baseline)
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.coo import (Graph, BatchUpdate, INF_D, apply_batch,
                              resolve_seed_weights)
from repro.core.engine import (RelaxEngine, RelaxPlan, gather_rows,
                               relax_rows, relax_sweep)
from repro.core.labelling import (
    HighwayLabelling, INF_KEY2, INF_KEY4,
    key2_dist, key2_hub, key2_make,
    key4_from_key2, key4_extend, key4_beta,
    per_plane_hub_mask,
)

_MAX_WAVES_CAP = 1 << 20  # safety valve; loops exit on fixpoint far earlier


def check_labelling_width(g: Graph, dist: jax.Array) -> None:
    """Trace-time guard: the labelling planes must span exactly g.n.

    Grow-in-place (DESIGN.md §6) resizes the graph and the labelling
    together at a version boundary; a caller that grows one without the
    other would otherwise surface as an opaque gather/broadcast shape
    error from deep inside the jitted fixpoints. Shapes are static, so
    this costs nothing at runtime.
    """
    if dist.shape[1] != g.n:
        raise ValueError(
            f"labelling planes span {dist.shape[1]} vertices but the graph "
            f"has n={g.n}; grow them together (core/growth.ensure_capacity, "
            f"or coo.grow + labelling.grow_labelling) before updating")


def _per_plane_hub_mask(labelling: HighwayLabelling, n: int) -> jax.Array:
    """[R, V] hub mask over the full plane set of a labelling."""
    return per_plane_hub_mask(labelling.landmarks, labelling.landmarks, n)


def _fixpoint(body_fn, init: jax.Array) -> jax.Array:
    """Iterate x <- body_fn(x) (monotone, elementwise) until unchanged."""
    def cond(state):
        _, changed, it = state
        return changed & (it < _MAX_WAVES_CAP)

    def body(state):
        x, _, it = state
        nx = body_fn(x)
        return nx, jnp.any(nx != x), it + 1

    out, _, _ = jax.lax.while_loop(cond, body,
                                   (init, jnp.asarray(True), jnp.asarray(0)))
    return out


# ---------------------------------------------------------------------------
# Frontier-proportional waves (change propagation, DESIGN.md §10)
# ---------------------------------------------------------------------------
#
# Every fixpoint below is a monotone Bellman-Ford-style iteration, so a
# vertex can improve at wave k only through an edge whose source changed
# at wave k-1 (an unchanged finite source re-proposes the candidate the
# destination already absorbed — the per-destination acceptance bounds are
# wave-invariant, so the filtered candidate is unchanged too). Tracking
# *changed destination blocks* per plane and relaxing only the tile rows
# one block-adjacency hop ahead of them is therefore exact, not a
# heuristic: the masked wave computes bit-identical planes to the full
# sweep. When the frontier densifies past the plan's static row budget
# (`FrontierTiles.rows_cap`, the autotunable density threshold) the wave
# falls back to the full sweep — a *correctness* requirement, since a
# truncated `nonzero(size=...)` would silently drop active rows — and the
# frontier keeps being tracked so later sparse waves re-enter the masked
# mode. The branch is a scalar `lax.cond` with the plane vmap *inside*
# each branch: a per-plane cond under vmap would lower to `select` and
# execute both branches every wave.

def frontier_active_rows(plan: RelaxPlan, front: jax.Array
                         ) -> tuple[jax.Array, jax.Array]:
    """(active-row flags [NR], count) one propagation hop ahead of the
    changed-block bitmap `front` [P, NBf]."""
    ft = plan.frontier
    rows = ft.active_rows(ft.propagate(jnp.any(front, axis=0)))
    return rows, jnp.sum(rows)


def frontier_wave(plan: RelaxPlan, g: Graph, full_step, masked_step,
                  x: jax.Array, front: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """One frontier wave: propagate, relax (masked or full), re-derive.

    `full_step(x)` is the existing whole-plane wave; `masked_step(x,
    rows_g)` the same wave restricted to the gathered rows (`rows_g`
    from `engine.gather_rows`, shared across planes). Returns (x',
    front') where front' marks the blocks whose values changed — the
    fixpoint is reached exactly when front' is empty, matching
    `_fixpoint`'s x' == x test.
    """
    ft = plan.frontier
    rows, count = frontier_active_rows(plan, front)

    def masked(x):
        ridx = jnp.nonzero(rows, size=ft.rows_cap,
                           fill_value=ft.nrows)[0].astype(jnp.int32)
        return masked_step(x, gather_rows(plan, g, ridx))

    nx = jax.lax.cond(count <= ft.rows_cap, masked, full_step, x)
    return nx, ft.changed_blocks(nx != x)


def _frontier_fixpoint(plan: RelaxPlan, g: Graph, full_step, masked_step,
                       init: jax.Array, front0: jax.Array) -> jax.Array:
    """Iterate `frontier_wave` until the changed-block frontier empties."""
    def cond(state):
        _, front, it = state
        return jnp.any(front) & (it < _MAX_WAVES_CAP)

    def body(state):
        x, front, it = state
        nx, nfront = frontier_wave(plan, g, full_step, masked_step, x, front)
        return nx, nfront, it + 1

    out, _, _ = jax.lax.while_loop(cond, body, (init, front0, jnp.asarray(0)))
    return out


def search_step_rows(rows_g, best: jax.Array, bound_g: jax.Array,
                     hub_mask: jax.Array | None, *,
                     improved: bool) -> jax.Array:
    """Masked twin of `search_{basic,improved}_step` over gathered rows.

    The full step's trailing `min(·, seed)` is dropped: the fixpoint
    starts at `best = seed` and is monotone decreasing, so the seed term
    is a no-op on every wave. The acceptance filter (Algo 2 line 12 /
    Algo 3 line 14) moves per-edge via `relax_rows(bound=...)`.
    """
    src_g, dstg, valid_g, w_g = rows_g
    if improved:
        def one(best_p, beta_p, hub_p):
            return relax_rows(best_p, best_p, src_g, dstg, valid_g, w_g,
                              4, INF_KEY4, hub=hub_p, clear_bit=2,
                              bound=beta_p)
        return jax.vmap(one)(best, bound_g, hub_mask)

    def one(best_p, dist_p):
        return relax_rows(best_p, best_p, src_g, dstg, valid_g, w_g,
                          1, INF_D, bound=dist_p)
    return jax.vmap(one)(best, bound_g)


def repair_step_rows(rows_g, cur: jax.Array, aff: jax.Array,
                     hub_mask: jax.Array) -> jax.Array:
    """Masked twin of `repair_step`: interior relaxation over gathered rows."""
    src_g, dstg, valid_g, w_g = rows_g

    def one(cur_p, aff_p, hub_p):
        emask = valid_g & aff_p[src_g] & aff_p[dstg]
        return relax_rows(cur_p, cur_p, src_g, dstg, emask, w_g,
                          2, INF_KEY2, hub=hub_p, clear_bit=1)
    return jax.vmap(one)(cur, aff, hub_mask)


def use_frontier(plan: RelaxPlan | None, g: Graph) -> bool:
    """Trace-time frontier dispatch: plan carries the tiling and the graph
    has edge slots (a zero-capacity snapshot has nothing to gather)."""
    return (plan is not None and plan.frontier is not None
            and g.src.shape[0] > 0)


# ---------------------------------------------------------------------------
# Batch Search — Algorithm 2 (basic, returns CP-affected superset)
# ---------------------------------------------------------------------------
#
# Each search below is decomposed into a *seed* (scatter the batch's anchor
# keys into per-plane planes) and a *step* (one relaxation wave over all
# planes). The monotone fixpoint of the step from the seed is the search
# result; the monolithic `*_planes` functions iterate it to convergence in
# one `while_loop`, and the serving pipeline (`core/snapshot.py`) iterates
# the *same* step in bounded chunks so query microbatches can interleave on
# the device queue — bit-identical by monotonicity (extra converged waves
# are no-ops).

def search_basic_seed(g_new: Graph, batch: BatchUpdate, dist_g: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Algo-2 seeds for a plane slice: (seed keys [P, V], seeded [P, V])."""
    n = g_new.n

    da = dist_g[:, batch.src]                                 # [P, U]
    db = dist_g[:, batch.dst]
    nontrivial = (da != db) & batch.valid[None, :]
    anchor = jnp.where(da < db, batch.dst[None, :], batch.src[None, :])
    d_pre = jnp.minimum(da, db)
    # Weighted seed: the anchor's candidate distance crosses the update's
    # edge at its seed weight (coo.resolve_seed_weights picks the superset-
    # safe one per op). No wrap guard needed: d_pre ≤ INF_D and w ≤ INF_D
    # keep the sum well under int32 max.
    seed_d = jnp.minimum(d_pre + batch.w[None, :], INF_D)
    seed_d = jnp.where(nontrivial, seed_d, INF_D)

    # Scatter-min seeds into per-plane planes.
    def scatter_seeds(anchors, vals):
        plane = jnp.full((n,), INF_D, jnp.int32)
        return plane.at[anchors].min(vals)
    seed = jax.vmap(scatter_seeds)(anchor, seed_d)            # [P, V]
    return seed, seed < INF_D                                 # anchors join
                                                              # V_AFF+ uncond.


def search_basic_step(plan: RelaxPlan | None, g_new: Graph, best: jax.Array,
                      seed: jax.Array, dist_g: jax.Array) -> jax.Array:
    """One Algo-2 relaxation wave over all planes of a slice [P, V]."""
    def one(best_p, seed_p, dist_p):
        cand = relax_sweep(plan, g_new, best_p, 1, INF_D)
        accept = cand <= dist_p                               # Algo2 line 12
        cand = jnp.where(accept, cand, INF_D)
        return jnp.minimum(best_p, jnp.minimum(cand, seed_p))
    return jax.vmap(one)(best, seed, dist_g)


def search_basic_planes(g_new: Graph, batch: BatchUpdate, dist_g: jax.Array,
                        plan: RelaxPlan | None = None) -> jax.Array:
    """Algo-2 search over an arbitrary plane slice `dist_g` [P, V].

    Entirely per-plane (the paper's landmark parallelism): `core/shard.py`
    runs this on each shard's local planes with no cross-shard traffic.
    """
    seed, seeded = search_basic_seed(g_new, batch, dist_g)
    if use_frontier(plan, g_new):
        best = _frontier_fixpoint(
            plan, g_new,
            lambda b: search_basic_step(plan, g_new, b, seed, dist_g),
            lambda b, rows_g: search_step_rows(rows_g, b, dist_g, None,
                                               improved=False),
            seed, plan.frontier.changed_blocks(seeded))
    else:
        best = _fixpoint(
            lambda b: search_basic_step(plan, g_new, b, seed, dist_g), seed)
    return seeded | (best < INF_D)


def batch_search_basic(g_old: Graph, g_new: Graph, batch: BatchUpdate,
                       labelling: HighwayLabelling,
                       plan: RelaxPlan | None = None) -> jax.Array:
    """Returns aff[R, V] bool — the CP-affected supersets, per landmark."""
    return search_basic_planes(g_new, batch, labelling.dist, plan)


# ---------------------------------------------------------------------------
# Batch Search — Algorithm 3 (improved, extended landmark lengths)
# ---------------------------------------------------------------------------

def search_improved_seed(g_new: Graph, batch: BatchUpdate,
                         dist_g: jax.Array, hub_g: jax.Array,
                         hub_mask: jax.Array
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Algo-3 seeds for a plane slice: (seed key4 [P, V], seeded, beta)."""
    n = g_new.n
    key2_g = key2_make(dist_g, hub_g)                         # [P, V]
    beta = key4_beta(key2_g)                                  # [P, V]

    da = dist_g[:, batch.src]
    db = dist_g[:, batch.dst]
    nontrivial = (da != db) & batch.valid[None, :]
    a_is_pre = da < db
    anchor = jnp.where(a_is_pre, batch.dst[None, :], batch.src[None, :])
    pre = jnp.where(a_is_pre, batch.src[None, :], batch.dst[None, :])

    key2_pre = jnp.take_along_axis(key2_g, pre, axis=1)       # [P, U]
    # Re-weights take the deletion-flavoured e-flag: like deletions they
    # can lengthen existing shortest paths, and e=True yields the smaller
    # (more inclusive) key4 — the superset-safe choice.
    k4 = key4_from_key2(key2_pre, (batch.is_del | batch.is_rew)[None, :])
    anchor_is_hub = jnp.take_along_axis(hub_mask, anchor, axis=1)
    seed_k4 = key4_extend(k4, anchor_is_hub, w=batch.w[None, :])
    seed_k4 = jnp.where(nontrivial, seed_k4, INF_KEY4)

    def scatter_seeds(anchors, vals):
        plane = jnp.full((n,), INF_KEY4, jnp.int32)
        return plane.at[anchors].min(vals)
    seed = jax.vmap(scatter_seeds)(anchor, seed_k4)
    return seed, seed < INF_KEY4, beta


def search_improved_step(plan: RelaxPlan | None, g_new: Graph,
                         best: jax.Array, seed: jax.Array, beta: jax.Array,
                         hub_mask: jax.Array) -> jax.Array:
    """One Algo-3 relaxation wave over all planes of a slice [P, V]."""
    def one(best_p, seed_p, beta_p, hub_p):
        # key4_extend per edge: +4, clamp, clear the l-bit at hub dsts.
        cand = relax_sweep(plan, g_new, best_p, 4, INF_KEY4,
                           hub=hub_p, clear_bit=2)
        accept = cand <= beta_p                               # Algo3 line 14
        cand = jnp.where(accept, cand, INF_KEY4)
        return jnp.minimum(best_p, jnp.minimum(cand, seed_p))
    return jax.vmap(one)(best, seed, beta, hub_mask)


def search_improved_planes(g_new: Graph, batch: BatchUpdate,
                           dist_g: jax.Array, hub_g: jax.Array,
                           hub_mask: jax.Array,
                           plan: RelaxPlan | None = None) -> jax.Array:
    """Algo-3 search over an arbitrary plane slice (dist/hub/hub_mask [P, V]).

    Entirely per-plane; `core/shard.py` runs it on shard-local planes.
    """
    seed, seeded, beta = search_improved_seed(g_new, batch, dist_g, hub_g,
                                              hub_mask)
    if use_frontier(plan, g_new):
        best = _frontier_fixpoint(
            plan, g_new,
            lambda b: search_improved_step(plan, g_new, b, seed, beta,
                                           hub_mask),
            lambda b, rows_g: search_step_rows(rows_g, b, beta, hub_mask,
                                               improved=True),
            seed, plan.frontier.changed_blocks(seeded))
    else:
        best = _fixpoint(
            lambda b: search_improved_step(plan, g_new, b, seed, beta,
                                           hub_mask),
            seed)
    return seeded | (best < INF_KEY4)


def batch_search_improved(g_old: Graph, g_new: Graph, batch: BatchUpdate,
                          labelling: HighwayLabelling,
                          plan: RelaxPlan | None = None) -> jax.Array:
    """Returns aff[R, V] bool ⊇ LD-affected vertices, per landmark."""
    hub_mask = _per_plane_hub_mask(labelling, g_new.n)
    return search_improved_planes(g_new, batch, labelling.dist, labelling.hub,
                                  hub_mask, plan)


# ---------------------------------------------------------------------------
# Batch Repair — Algorithm 4
# ---------------------------------------------------------------------------

def repair_base(plan: RelaxPlan | None, g_new: Graph, aff: jax.Array,
                key2_g: jax.Array, hub_mask: jax.Array) -> jax.Array:
    """Algo-4 boundary seeds: landmark-distance bounds from *unaffected*
    neighbours (line 3), INF_KEY2 off the affected sets. [P, V]."""
    def one(aff_p, key2_p, hub_p):
        bou_mask = g_new.valid & ~aff_p[g_new.src] & aff_p[g_new.dst]
        base = relax_sweep(plan, g_new, key2_p, 2, INF_KEY2,
                           hub=hub_p, clear_bit=1, edge_mask=bou_mask)
        return jnp.where(aff_p, base, INF_KEY2)
    return jax.vmap(one)(aff, key2_g, hub_mask)


def repair_base_frontier(plan: RelaxPlan, g_new: Graph, aff: jax.Array,
                         key2_g: jax.Array, hub_mask: jax.Array
                         ) -> jax.Array:
    """Masked `repair_base`: one sweep over the affected sets' blocks.

    Boundary edges end on affected vertices, so the rows of the blocks
    holding *any* plane's affected vertices cover every boundary edge of
    every plane — no propagation hop needed. Falls back to the full
    sweep when the affected footprint overflows the row budget.
    """
    ft = plan.frontier
    rows = ft.active_rows(ft.changed_blocks(jnp.any(aff, axis=0)))

    def masked(args):
        aff, key2_g, hub_mask = args
        ridx = jnp.nonzero(rows, size=ft.rows_cap,
                           fill_value=ft.nrows)[0].astype(jnp.int32)
        src_g, dstg, valid_g, w_g = gather_rows(plan, g_new, ridx)

        def one(aff_p, key2_p, hub_p):
            emask = valid_g & ~aff_p[src_g] & aff_p[dstg]
            base = relax_rows(key2_p, jnp.full_like(key2_p, INF_KEY2),
                              src_g, dstg, emask, w_g, 2, INF_KEY2,
                              hub=hub_p, clear_bit=1)
            return jnp.where(aff_p, base, INF_KEY2)
        return jax.vmap(one)(aff, key2_g, hub_mask)

    def full(args):
        aff, key2_g, hub_mask = args
        return repair_base(plan, g_new, aff, key2_g, hub_mask)

    return jax.lax.cond(jnp.sum(rows) <= ft.rows_cap, masked, full,
                        (aff, key2_g, hub_mask))


def repair_step(plan: RelaxPlan | None, g_new: Graph, cur: jax.Array,
                aff: jax.Array, hub_mask: jax.Array) -> jax.Array:
    """One Algo-4 interior relaxation wave (lines 5-15) over a slice."""
    def one(cur_p, aff_p, hub_p):
        int_mask = g_new.valid & aff_p[g_new.src] & aff_p[g_new.dst]
        cand = relax_sweep(plan, g_new, cur_p, 2, INF_KEY2,
                           hub=hub_p, clear_bit=1, edge_mask=int_mask)
        return jnp.minimum(cur_p, cand)
    return jax.vmap(one)(cur, aff, hub_mask)


def repair_merge(aff: jax.Array, settled: jax.Array,
                 key2_g: jax.Array) -> jax.Array:
    """Rewrite only affected entries; unaffected labels are untouched."""
    return jnp.where(aff, settled, key2_g)


def repair_planes(g_new: Graph, aff: jax.Array, key2_g: jax.Array,
                  hub_mask: jax.Array,
                  plan: RelaxPlan | None = None) -> jax.Array:
    """Algo-4 repair over an arbitrary plane slice; returns new key2 [P, V].

    The paper's ascending-distance wavefront (settle V_min, relax neighbors)
    is realized as a boundary-seeded relaxation fixpoint: identical final
    values by Lemma 5.20 + monotonicity. Entirely per-plane, so
    `core/shard.py` runs it on shard-local planes.
    """
    if use_frontier(plan, g_new):
        base = repair_base_frontier(plan, g_new, aff, key2_g, hub_mask)
        settled = _frontier_fixpoint(
            plan, g_new,
            lambda c: repair_step(plan, g_new, c, aff, hub_mask),
            lambda c, rows_g: repair_step_rows(rows_g, c, aff, hub_mask),
            base, plan.frontier.changed_blocks(base < INF_KEY2))
    else:
        base = repair_base(plan, g_new, aff, key2_g, hub_mask)
        settled = _fixpoint(
            lambda c: repair_step(plan, g_new, c, aff, hub_mask), base)
    return repair_merge(aff, settled, key2_g)


def batch_repair(g_new: Graph, aff: jax.Array,
                 labelling: HighwayLabelling,
                 plan: RelaxPlan | None = None) -> HighwayLabelling:
    """Settle d^L_{G'} on the affected sets and rewrite labels minimally."""
    hub_mask = _per_plane_hub_mask(labelling, g_new.n)
    new_key2 = repair_planes(g_new, aff, labelling.key2(), hub_mask, plan)
    dist = jnp.minimum(key2_dist(new_key2), INF_D)
    hub = key2_hub(new_key2) & (dist < INF_D)
    highway = dist[jnp.arange(labelling.num_landmarks)[:, None],
                   labelling.landmarks[None, :]]
    return HighwayLabelling(labelling.landmarks, dist, hub, highway)


# ---------------------------------------------------------------------------
# BatchHL — Algorithm 1
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("improved",))
def batchhl_update(g_old: Graph, batch: BatchUpdate,
                   labelling: HighwayLabelling, improved: bool = True,
                   plan: RelaxPlan | None = None,
                   g_new: Graph | None = None
                   ) -> tuple[Graph, HighwayLabelling, jax.Array]:
    """One BatchHL step: apply B, search, repair. Returns (G', Γ', aff).

    `plan` selects the sweep backend (engine.RelaxEngine.prepare); it must
    be prepared from the *post-update* snapshot G' = apply_batch(g_old,
    batch) so the tiling covers edges the batch inserts (launch/serve.py
    shows the amortized pattern). plan=None runs the jnp reference.
    Callers that already materialized G' (typically for that prepare) can
    pass it as `g_new` to skip the recompute; it must equal
    apply_batch(g_old, batch).
    """
    check_labelling_width(g_old, labelling.dist)
    if g_new is None:
        g_new = apply_batch(g_old, batch)
    # Seeds for deletions / re-weights must cross the edge at its
    # pre-update weight (resp. min of old/new) — resolved against g_old;
    # apply_batch above takes the *original* batch (post-update weights).
    batch = resolve_seed_weights(g_old, batch)
    search = batch_search_improved if improved else batch_search_basic
    aff = search(g_old, g_new, batch, labelling, plan)
    new_labelling = batch_repair(g_new, aff, labelling, plan)
    return g_new, new_labelling, aff


def batchhl_update_split(g_old: Graph, batch: BatchUpdate,
                         labelling: HighwayLabelling, improved: bool = True,
                         engine: RelaxEngine | None = None):
    """BHL^s: insertions and deletions as two sequential sub-batches.

    Takes the `RelaxEngine` (not a plan): the tiling must cover the
    intermediate insertion-applied snapshot, and the deletion sub-batch then
    reuses it unchanged (deletions never move topology slots).
    """
    # Re-weights ride the deletion sub-batch: like deletions they touch a
    # live slot and never move topology, so the tiling prepared for the
    # insertion-applied snapshot stays valid through them.
    ins = dataclasses.replace(
        batch, valid=batch.valid & ~batch.is_del & ~batch.is_rew)
    dele = dataclasses.replace(
        batch, valid=batch.valid & (batch.is_del | batch.is_rew))
    plan = None
    g_ins = None
    if engine is not None:
        g_ins = apply_batch(g_old, ins)
        plan = engine.prepare(g_ins)
    g1, lab1, aff1 = batchhl_update(g_old, ins, labelling, improved, plan,
                                    g_new=g_ins)
    if engine is not None:
        # The deletion sub-batch only flips validity bits of the snapshot
        # just tiled — structurally safe, skip the fingerprint sync.
        plan = engine.prepare(g1, topology_changed=False, verify_cache=False)
    g2, lab2, aff2 = batchhl_update(g1, dele, lab1, improved, plan)
    return g2, lab2, aff1 | aff2


def uhl_update(g_old: Graph, batch: BatchUpdate,
               labelling: HighwayLabelling, improved: bool = True,
               engine: RelaxEngine | None = None):
    """UHL+: the single-update baseline — one BatchHL call per update.

    With an engine, re-tiles only on insertion steps (deletions reuse the
    cached tiling) — the per-update amortization the engine contract allows.
    """
    g, lab = g_old, labelling
    total_aff = jnp.zeros_like(labelling.hub)
    u = batch.src.shape[0]
    # One device→host pull for the whole loop: indexing the device arrays
    # inside it (bool(~batch.is_del[i] & ...)) would force a blocking sync
    # per update, serializing the unit-update baseline on transfer latency.
    is_del_h = np.asarray(batch.is_del)
    is_rew_h = np.asarray(batch.is_rew)
    valid_h = np.asarray(batch.valid)
    for i in range(u):
        single = BatchUpdate(batch.src[i:i + 1], batch.dst[i:i + 1],
                             batch.is_del[i:i + 1], batch.valid[i:i + 1],
                             batch.w[i:i + 1], batch.is_rew[i:i + 1])
        plan, g_next = None, None
        if engine is not None:
            # Only insertions move topology slots; deletions and
            # re-weights touch live slots in place.
            is_ins = bool(~is_del_h[i] & ~is_rew_h[i] & valid_h[i])
            g_next = apply_batch(g, single)
            # Deletion steps only flip validity bits of the snapshot the
            # engine last tiled — structurally safe, so skip the
            # fingerprint's per-step host sync (see engine.prepare).
            plan = engine.prepare(g_next, topology_changed=is_ins,
                                  verify_cache=False)
        g, lab, aff = batchhl_update(g, single, lab, improved, plan,
                                     g_new=g_next)
        total_aff = total_aff | aff
    return g, lab, total_aff
