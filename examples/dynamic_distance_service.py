"""End-to-end driver: a BatchHL distance-query service under churn.

Simulates the paper's serving scenario: a power-law network receives
batches of edge updates while answering distance-query traffic; the
labelling is maintained incrementally (never rebuilt), checkpointed, and
verified against a BFS oracle each tick.

    PYTHONPATH=src python examples/dynamic_distance_service.py
"""
import subprocess
import sys

if __name__ == "__main__":
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve",
         "--n", "3000", "--batches", "4", "--batch-size", "120",
         "--queries", "256", "--verify",
         "--ckpt-dir", "/tmp/repro_service_ckpt"],
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"}))
