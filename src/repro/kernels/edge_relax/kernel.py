"""Blocked edge-relaxation kernel: the BatchHL wave hot loop.

    cand[v] = min over edges (u, v)   keys[u] + step        (then min w/ keys)

TPU adaptation of the paper's adjacency-list traversal: edges are pre-tiled
by destination block (CSR-style reordering done once per graph, amortized
over all waves of all batches), so each grid step owns a disjoint slice of
the output vertices — no cross-block write races, no atomics. Within a
block the kernel gathers source keys from the VMEM-resident key plane
(per-device vertex shard: V_local ≤ ~1M keys = 4 MB, fits VMEM) and
scatter-mins into the local [BV] output tile.

Working set per grid step: keys (full shard) + BE·3·4 B edge slice +
BV·4 B out tile. For BV=512, BE=4096: ≈ 64 KB on top of the key plane.

This kernel regime is the sparse/SpMM family (kernel_taxonomy §B.3/§B.11):
gather → elementwise → segment-reduce. The MXU is idle; the roofline is
HBM-bandwidth on the edge slices + VMEM gather throughput.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

INF32 = 1 << 29  # plain int: pallas kernels must not capture traced constants


def _relax_kernel(keys_ref, src_ref, dstloc_ref, valid_ref, step_ref, o_ref):
    keys = keys_ref[...]          # [V] int32 (full shard)
    src = src_ref[...]            # [1, BE]
    dstloc = dstloc_ref[...]      # [1, BE] local dst in [0, BV)
    valid = valid_ref[...]        # [1, BE] int32 mask
    step = step_ref[0]

    gathered = jnp.take(keys, src[0], axis=0)
    cand = jnp.minimum(gathered + step, INF32)
    cand = jnp.where(valid[0] != 0, cand, INF32)
    out = jnp.full((o_ref.shape[-1],), INF32, jnp.int32)
    out = out.at[dstloc[0]].min(cand)
    o_ref[...] = out[None, :]


def block_edges(src: np.ndarray, dst: np.ndarray, valid: np.ndarray,
                n: int, block_v: int, block_e: int | None = None):
    """Host-side tiling: group edges by destination block of size block_v.

    Returns (src_t [NB, BE], dstloc_t [NB, BE], valid_t [NB, BE], block_v).
    Done once per graph topology; validity churn from batch updates only
    rewrites the valid plane.
    """
    nb = -(-n // block_v)
    order = np.argsort(dst // block_v, kind="stable")
    src, dst, valid = src[order], dst[order], valid[order]
    counts = np.bincount(dst // block_v, minlength=nb)
    be = block_e or max(int(counts.max()), 8)
    src_t = np.zeros((nb, be), np.int32)
    dst_t = np.zeros((nb, be), np.int32)
    val_t = np.zeros((nb, be), np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)])
    for b in range(nb):
        lo, hi = starts[b], starts[b + 1]
        m = min(hi - lo, be)
        src_t[b, :m] = src[lo:lo + m]
        dst_t[b, :m] = dst[lo:lo + m] - b * block_v
        val_t[b, :m] = valid[lo:lo + m]
    return src_t, dst_t, val_t, block_v


@functools.partial(jax.jit, static_argnames=("n", "block_v", "interpret"))
def edge_relax_pallas(keys: jax.Array, src_t: jax.Array, dstloc_t: jax.Array,
                      valid_t: jax.Array, step: jax.Array, n: int,
                      block_v: int, interpret: bool = True) -> jax.Array:
    """keys [V] int32 + tiled edges → cand [V] int32 (min-relaxed)."""
    nb, be = src_t.shape
    npad = nb * block_v
    step_arr = jnp.full((1,), step, jnp.int32)

    out = pl.pallas_call(
        _relax_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec(keys.shape, lambda i: (0,) * keys.ndim),
            pl.BlockSpec((1, be), lambda i: (i, 0)),
            pl.BlockSpec((1, be), lambda i: (i, 0)),
            pl.BlockSpec((1, be), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block_v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block_v), jnp.int32),
        interpret=interpret,
    )(keys, src_t, dstloc_t, valid_t, step_arr)
    return out.reshape(npad)[:n]
