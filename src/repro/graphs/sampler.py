"""Uniform fanout neighbor sampler (GraphSAGE-style) for minibatch training.

Produces fixed-shape padded subgraphs from a CSR adjacency: for each seed
node, sample `fanout[0]` neighbors, then `fanout[1]` neighbors of those, etc.
All shapes are static (batch_nodes × prod(fanouts)), so the sampled blocks
feed straight into jit'd train steps. Optionally biases sampling toward
vertices close to BatchHL landmarks (distance labels as a sampling prior —
the paper's labelling doubling as pipeline metadata).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.tree_util.register_dataclass,
         data_fields=("indptr", "indices"), meta_fields=("n",))
@dataclasses.dataclass(frozen=True)
class CSR:
    indptr: jax.Array   # int32[V+1]
    indices: jax.Array  # int32[E]
    n: int


def build_csr(n: int, edges: np.ndarray) -> CSR:
    """CSR from undirected [E,2] numpy edges (both directions)."""
    src = np.concatenate([edges[:, 0], edges[:, 1]])
    dst = np.concatenate([edges[:, 1], edges[:, 0]])
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n + 1, np.int32)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    return CSR(jnp.asarray(indptr), jnp.asarray(dst.astype(np.int32)), n)


@partial(jax.jit, static_argnames=("fanout",))
def sample_neighbors(csr: CSR, seeds: jax.Array, fanout: int,
                     key: jax.Array,
                     bias: jax.Array | None = None) -> tuple[jax.Array,
                                                             jax.Array]:
    """For each seed, sample `fanout` neighbors with replacement.

    Returns (neighbors [B, fanout] int32, mask [B, fanout] bool). Isolated
    seeds get mask=False. With `bias` (per-vertex non-negative scores, e.g.
    closeness to BatchHL landmarks), neighbors are drawn ∝ bias via Gumbel
    trick over the padded candidate window.
    """
    deg = csr.indptr[seeds + 1] - csr.indptr[seeds]        # [B]
    b = seeds.shape[0]
    u = jax.random.uniform(key, (b, fanout))
    offs = (u * jnp.maximum(deg, 1)[:, None]).astype(jnp.int32)
    if bias is not None:
        # Draw fanout candidates twice and keep the higher-bias pick.
        u2 = jax.random.uniform(jax.random.fold_in(key, 1), (b, fanout))
        offs2 = (u2 * jnp.maximum(deg, 1)[:, None]).astype(jnp.int32)
        n1 = csr.indices[csr.indptr[seeds][:, None] + offs]
        n2 = csr.indices[csr.indptr[seeds][:, None] + offs2]
        take2 = bias[n2] > bias[n1]
        nbrs = jnp.where(take2, n2, n1)
    else:
        nbrs = csr.indices[csr.indptr[seeds][:, None] + offs]
    mask = jnp.broadcast_to(deg[:, None] > 0, nbrs.shape)
    return jnp.where(mask, nbrs, 0), mask


def sample_subgraph(csr: CSR, seeds: jax.Array, fanouts: tuple[int, ...],
                    key: jax.Array, bias: jax.Array | None = None):
    """Multi-hop sampled block: returns per-hop (nodes, mask) lists plus
    flattened (src, dst, edge_mask) COO of the sampled bipartite edges."""
    layers = [(seeds, jnp.ones(seeds.shape, bool))]
    srcs, dsts, masks = [], [], []
    cur, cur_mask = seeds, jnp.ones(seeds.shape, bool)
    for hop, f in enumerate(fanouts):
        nbrs, m = sample_neighbors(csr, cur.reshape(-1), f,
                                   jax.random.fold_in(key, hop), bias)
        m = m & cur_mask.reshape(-1)[:, None]
        srcs.append(nbrs.reshape(-1))
        dsts.append(jnp.repeat(cur.reshape(-1), f))
        masks.append(m.reshape(-1))
        cur, cur_mask = nbrs, m
        layers.append((cur.reshape(-1), cur_mask.reshape(-1)))
    return layers, (jnp.concatenate(srcs), jnp.concatenate(dsts),
                    jnp.concatenate(masks))
