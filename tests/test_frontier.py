"""Frontier-proportional sweeps (DESIGN.md §10): masked ≡ full, bit-for-bit.

Property suite for the change-propagation update path: on random
connected graphs with random mixed batches (insert / delete /
re-weight, weighted and unweighted), an engine with frontier tracking
on must produce *exactly* the labelling of the full-sweep reference —
same planes, same affected set — on both backends. The density
threshold is swept across its boundary behaviours: a threshold so small
that every wave overflows ``rows_cap`` and takes the full-sweep
fallback branch, the default 0.25, and 1.0 (the masked branch whenever
the frontier is nonempty). Bit-identity is the whole contract — the
frontier is a performance mode, never an approximation — so every
assertion here is exact array equality, not allclose.

A slow-marked subprocess repeats the check on a forced 8-device host
mesh through the pipelined chunked updater (the `shard_*_frontier`
twins), against the unsharded full-sweep reference.
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:  # optional dep: the drawn-case layer; the seeded grid always runs
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.graphs import generators as gen
from repro.graphs.coo import apply_batch, from_edges, make_batch
from repro.core.batch import batchhl_update
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.engine import RelaxEngine

BACKENDS = ("jnp", "pallas")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True, scope="module")
def _fresh_compile_caches():
    """The parity grid compiles many frontier fixpoints (per backend ×
    threshold × batch mix). Bracket the module with cache drops — same
    hygiene as test_weighted.py — so those executables neither sit on a
    few hundred accumulated ones nor stay live under the rest of the
    suite (the single XLA CPU client has segfaulted a later shard_map
    compile when the process-wide executable count climbed too far)."""
    jax.clear_caches()
    yield
    jax.clear_caches()


def _assert_same(ref, got, context):
    g_ref, lab_ref, aff_ref = ref
    g_got, lab_got, aff_got = got
    np.testing.assert_array_equal(np.asarray(aff_ref), np.asarray(aff_got),
                                  err_msg=f"aff {context}")
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(
            np.asarray(getattr(lab_ref, f)), np.asarray(getattr(lab_got, f)),
            err_msg=f"{f} {context}")


def _one_tick(g, batch, lab, g_next, engine):
    plan = engine.prepare(g_next) if engine is not None else None
    return batchhl_update(g, batch, lab, plan=plan, g_new=g_next)


def _check_case(backend, n, seed, n_ins, n_del, n_rew, max_w, threshold,
                improved):
    edges = gen.random_connected(n, extra_edges=n // 2, seed=seed)
    g = from_edges(n, edges, edges.shape[0] + 16)
    lab = build_labelling(g, select_landmarks_by_degree(g, 3))
    ups = gen.random_batch_updates(edges, n, n_ins, n_del, seed=seed + 1,
                                   n_rew=n_rew, max_weight=max_w)
    batch = make_batch(ups, pad_to=max(len(ups), 1) + 2)
    if not ups:  # all-padding batch: a no-op update
        batch = dataclasses.replace(batch, valid=jnp.zeros_like(batch.valid))
    g_next = apply_batch(g, batch)

    ref_engine = (None if backend == "jnp"
                  else RelaxEngine(backend="pallas", block_v=16))
    ref = batchhl_update(g, batch, lab, improved,
                         plan=(ref_engine.prepare(g_next)
                               if ref_engine else None),
                         g_new=g_next)
    fr_engine = RelaxEngine(backend=backend, block_v=16, frontier=True,
                            frontier_threshold=threshold, frontier_block=8)
    got = batchhl_update(g, batch, lab, improved,
                         plan=fr_engine.prepare(g_next), g_new=g_next)
    _assert_same(ref, got,
                 f"[backend={backend} th={threshold} improved={improved}]")


# Representative corners, one per row: pure inserts, pure deletes, pure
# re-weights, a weighted mixed batch, the empty batch, masked-always
# (th=1.0), and fallback-always (th=0.01). Runs in every environment —
# the hypothesis layer below widens the net when the dep is present.
CASES = [
    # (n, seed, n_ins, n_del, n_rew, max_w, threshold, improved)
    (24, 3, 3, 0, 0, 1, 0.25, True),
    (24, 4, 0, 3, 0, 1, 0.25, True),
    (24, 5, 0, 0, 2, 4, 0.25, True),
    (36, 6, 2, 2, 2, 3, 0.25, False),
    (18, 7, 0, 0, 0, 1, 0.25, True),
    (30, 8, 2, 2, 1, 2, 1.0, True),
    (30, 9, 2, 2, 1, 2, 0.01, True),
]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("case", CASES,
                         ids=[f"n{c[0]}s{c[1]}" for c in CASES])
def test_frontier_update_bit_identical(backend, case):
    """Masked ≡ full across mixed batches, backends, and the threshold's
    boundary behaviours (fallback-always / default / masked-always)."""
    _check_case(backend, *case)


if HAVE_HYPOTHESIS:
    @pytest.mark.parametrize("backend", BACKENDS)
    @given(data=st.data())
    @settings(deadline=None, max_examples=8,
              suppress_health_check=[HealthCheck.too_slow,
                                     HealthCheck.differing_executors])
    def test_frontier_update_bit_identical_drawn(backend, data):
        _check_case(
            backend,
            n=data.draw(st.integers(12, 36), label="n"),
            seed=data.draw(st.integers(0, 10_000), label="seed"),
            n_ins=data.draw(st.integers(0, 3), label="n_ins"),
            n_del=data.draw(st.integers(0, 3), label="n_del"),
            n_rew=data.draw(st.integers(0, 2), label="n_rew"),
            max_w=data.draw(st.integers(1, 4), label="max_weight"),
            threshold=data.draw(st.sampled_from((0.01, 0.25, 1.0)),
                                label="frontier_threshold"),
            improved=data.draw(st.booleans(), label="improved"))


@pytest.mark.parametrize("backend", BACKENDS)
def test_threshold_fallback_boundary(backend):
    """rows_cap boundary: thresholds straddling the exact active-row
    count flip between the masked branch and the full-sweep fallback —
    both must be bit-identical to the reference (the cond is a routing
    decision, not a semantic one)."""
    n = 40
    edges = gen.random_connected(n, extra_edges=20, seed=7)
    g = from_edges(n, edges, edges.shape[0] + 16)
    lab = build_labelling(g, select_landmarks_by_degree(g, 3))
    ups = gen.random_batch_updates(edges, n, n_ins=2, n_del=2, seed=8,
                                   n_rew=1, max_weight=3)
    batch = make_batch(ups, pad_to=8)
    g_next = apply_batch(g, batch)
    ref = _one_tick(g, batch, lab, g_next,
                    None if backend == "jnp"
                    else RelaxEngine(backend="pallas", block_v=16))
    nrows = RelaxEngine(backend=backend, block_v=16, frontier=True,
                        frontier_block=8).prepare(g_next).frontier.nrows
    # One threshold per achievable rows_cap regime around the boundary:
    # cap=1 (overflow on any multi-row wave), cap≈half, cap=nrows.
    for th in (1.0 / nrows, 0.5, 1.0):
        eng = RelaxEngine(backend=backend, block_v=16, frontier=True,
                          frontier_threshold=th, frontier_block=8)
        got = _one_tick(g, batch, lab, g_next, eng)
        _assert_same(ref, got, f"[backend={backend} th={th}]")


_MESH_SCRIPT = textwrap.dedent("""
    import numpy as np, jax.numpy as jnp
    from repro.graphs import generators as gen
    from repro.graphs.coo import from_edges, make_batch, apply_batch
    from repro.core.construct import (build_labelling,
                                      select_landmarks_by_degree)
    from repro.core.engine import RelaxEngine
    from repro.core.batch import batchhl_update
    from repro.core.snapshot import (Snapshot, pipelined_update,
                                     run_pipelined_update)
    from repro.launch.mesh import make_host_mesh

    import jax
    assert len(jax.devices()) == 8, jax.devices()
    n, deg = 300, 3
    edges = gen.barabasi_albert(n, deg, seed=0)
    g = from_edges(n, edges, edges.shape[0] + 64)
    lab = build_labelling(g, select_landmarks_by_degree(g, 8))
    ups = gen.random_batch_updates(edges, n, n_ins=3, n_del=3, seed=2,
                                   n_rew=1, max_weight=3)
    batch = make_batch(ups, pad_to=8)
    g_new = apply_batch(g, batch)
    _, labref, affref = batchhl_update(g, batch, lab, True, None)
    mesh = make_host_mesh(model=2)
    for backend in ("jnp", "pallas"):
        for fused in (False, True):
            eng = RelaxEngine(backend=backend, block_v=64, frontier=True)
            plan = eng.prepare(g_new)
            snap = Snapshot(0, g, lab, plan)
            s1, aff = run_pipelined_update(pipelined_update(
                snap, batch, plan=plan, g_new=g_new, mesh=mesh,
                improved=True, chunk_sweeps=2, fused=fused))
            assert bool(jnp.all(aff == affref)), (backend, fused)
            for f in ("dist", "hub", "highway"):
                assert bool(jnp.all(getattr(s1.labelling, f)
                                    == getattr(labref, f))), \\
                    (backend, fused, f)
    print("MESH FRONTIER PARITY OK")
""")


@pytest.mark.slow
def test_frontier_mesh_multidevice_parity(tmp_path):
    """Masked ≡ full through the sharded pipelined updater on a forced
    8-device host mesh, both backends, fused and unfused."""
    script = tmp_path / "mesh_frontier_parity.py"
    script.write_text(_MESH_SCRIPT)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (os.path.join(REPO, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, str(script)], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "MESH FRONTIER PARITY OK" in out.stdout, out.stdout
