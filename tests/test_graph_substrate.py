"""Graph substrate: padded COO updates, samplers, segment wrappers."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests only; optional dep
pytestmark = pytest.mark.slow  # property tests: full CI job only
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.graphs import generators as gen
from repro.graphs.coo import from_edges, make_batch, apply_batch, to_numpy_adj
from repro.graphs.sampler import build_csr, sample_neighbors, sample_subgraph
from repro.graphs.segment import (masked_segment_min, masked_segment_sum,
                                  masked_segment_mean)
from repro.core import ref

SETTINGS = dict(deadline=None, max_examples=20,
                suppress_health_check=[HealthCheck.too_slow])


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(6, 40),
       n_ins=st.integers(0, 6), n_del=st.integers(0, 6))
def test_apply_batch_matches_set_semantics(seed, n, n_ins, n_del):
    edges = gen.random_connected(n, extra_edges=n // 3, seed=seed)
    g = from_edges(n, edges, edges.shape[0] + 2 * (n_ins + 1))
    ups = gen.random_batch_updates(edges, n, n_ins=n_ins, n_del=n_del,
                                   seed=seed + 1)
    batch = make_batch(ups, pad_to=max(len(ups), 1))
    g2 = apply_batch(g, batch)
    assert to_numpy_adj(g2) == ref.apply_updates(to_numpy_adj(g), ups)


def test_apply_batch_capacity_reuse():
    """Freed slots from deletions are reused by later insertions."""
    edges = np.array([[0, 1], [1, 2], [2, 3], [3, 0]], np.int32)
    g = from_edges(4, edges, 5)  # capacity for only one extra edge
    b1 = make_batch([(0, 1, True), (1, 2, True)], pad_to=2)
    g = apply_batch(g, b1)
    b2 = make_batch([(0, 2, False), (1, 3, False)], pad_to=2)
    g = apply_batch(g, b2)  # needs the freed slots
    assert to_numpy_adj(g) == {0: {2, 3}, 1: {3}, 2: {0, 3}, 3: {0, 1, 2}}


def test_sampler_returns_real_neighbors():
    rng = np.random.default_rng(0)
    edges = gen.barabasi_albert(200, 3, seed=1)
    csr = build_csr(200, edges)
    adj = {}
    for u, v in edges:
        adj.setdefault(int(u), set()).add(int(v))
        adj.setdefault(int(v), set()).add(int(u))
    seeds = jnp.asarray(rng.integers(0, 200, 64), jnp.int32)
    nbrs, mask = sample_neighbors(csr, seeds, 8, jax.random.PRNGKey(0))
    nbrs, mask = np.asarray(nbrs), np.asarray(mask)
    for i, s in enumerate(np.asarray(seeds)):
        for j in range(8):
            if mask[i, j]:
                assert int(nbrs[i, j]) in adj.get(int(s), set())


def test_sample_subgraph_shapes_static():
    edges = gen.barabasi_albert(300, 3, seed=2)
    csr = build_csr(300, edges)
    seeds = jnp.arange(16, dtype=jnp.int32)
    layers, (src, dst, mask) = sample_subgraph(
        csr, seeds, (4, 3), jax.random.PRNGKey(1))
    assert layers[1][0].shape == (16 * 4,)
    assert layers[2][0].shape == (16 * 4 * 3,)
    assert src.shape == dst.shape == mask.shape == (16 * 4 + 16 * 4 * 3,)


def test_sampler_bias_prefers_high_bias_vertices():
    # star graph: vertex 0 connected to all others
    edges = np.array([[0, i] for i in range(1, 51)], np.int32)
    csr = build_csr(51, edges)
    bias = jnp.zeros(51).at[1].set(100.0)  # strongly prefer vertex 1
    seeds = jnp.zeros(64, jnp.int32)
    nbrs, _ = sample_neighbors(csr, seeds, 4, jax.random.PRNGKey(2),
                               bias=bias)
    frac_v1 = float(jnp.mean((nbrs == 1).astype(jnp.float32)))
    nbrs0, _ = sample_neighbors(csr, seeds, 4, jax.random.PRNGKey(2))
    frac_v1_unbiased = float(jnp.mean((nbrs0 == 1).astype(jnp.float32)))
    assert frac_v1 > frac_v1_unbiased


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 50),
       e=st.integers(1, 200))
def test_segment_wrappers_vs_numpy(seed, n, e):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 100, e).astype(np.int32)
    seg = rng.integers(0, n, e).astype(np.int32)
    mask = rng.random(e) < 0.6
    fill = jnp.int32(1 << 20)
    got = masked_segment_min(jnp.asarray(data), jnp.asarray(seg), n,
                             jnp.asarray(mask), fill)
    want = np.full(n, 1 << 20, np.int64)
    for i in range(e):
        if mask[i]:
            want[seg[i]] = min(want[seg[i]], data[i])
    np.testing.assert_array_equal(np.asarray(got), want)

    fdata = rng.normal(size=(e, 3)).astype(np.float32)
    got_sum = masked_segment_sum(jnp.asarray(fdata), jnp.asarray(seg), n,
                                 jnp.asarray(mask))
    want_sum = np.zeros((n, 3), np.float32)
    for i in range(e):
        if mask[i]:
            want_sum[seg[i]] += fdata[i]
    np.testing.assert_allclose(np.asarray(got_sum), want_sum, rtol=1e-5,
                               atol=1e-5)

    got_mean = masked_segment_mean(jnp.asarray(fdata), jnp.asarray(seg), n,
                                   jnp.asarray(mask))
    cnt = np.zeros(n)
    for i in range(e):
        if mask[i]:
            cnt[seg[i]] += 1
    want_mean = want_sum / np.maximum(cnt, 1)[:, None]
    np.testing.assert_allclose(np.asarray(got_mean), want_mean, rtol=1e-5,
                               atol=1e-5)
