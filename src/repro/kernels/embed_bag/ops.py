"""Jit'd wrapper for embedding-bag with mean/sum modes and masking."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.embed_bag import kernel, ref


def embed_bag(table: jax.Array, idx: jax.Array,
              mask: jax.Array | None = None, mode: str = "sum",
              use_pallas: bool | None = None) -> jax.Array:
    """EmbeddingBag(table, idx) with optional validity mask.

    table [N, D]; idx [B, L] int32; mask [B, L] bool. mode ∈ {sum, mean}.
    """
    b, l = idx.shape
    w = jnp.ones((b, l), jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
        idx = jnp.where(mask, idx, 0)
    if mode == "mean":
        denom = jnp.maximum(w.sum(axis=1, keepdims=True), 1.0)
        w = w / denom
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        interpret = jax.default_backend() != "tpu"
        return kernel.embed_bag_pallas(table, idx, w, interpret=interpret)
    return ref.embed_bag(table, idx, w)
