"""Paper Table 5 / Figure 2: number of affected vertices — BHL vs BHL⁺ vs
the single-update setting (UHL), across delete/add/mix batches and across
batch sizes. Reproduces the paper's core observation: improved batch search
prunes away a large fraction of CP-affected vertices, and batch processing
avoids the repeated-vertex blowup of single-update processing.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.graphs.coo import make_batch, apply_batch, BatchUpdate
from repro.core.batch import batch_search_basic, batch_search_improved
from benchmarks import common as cm

DATASETS = ("ba_2k", "ba_10k")
MODES = ("decremental", "incremental", "mixed")
BATCH = 128
FIG2_SIZES = (16, 32, 64, 128, 256)


def _affected_counts(inst, ups, batch_size):
    b = make_batch(ups, pad_to=batch_size)
    g2 = apply_batch(inst.g, b)
    basic = int(jnp.sum(batch_search_basic(inst.g, g2, b, inst.lab)))
    improved = int(jnp.sum(batch_search_improved(inst.g, g2, b, inst.lab)))
    # single-update: sum of per-update affected sets (repeated work)
    uhl = 0
    g, lab = inst.g, inst.lab
    from repro.core.batch import batchhl_update
    for i in range(len(ups)):
        single = BatchUpdate(b.src[i:i + 1], b.dst[i:i + 1],
                             b.is_del[i:i + 1], b.valid[i:i + 1],
                             b.w[i:i + 1], b.is_rew[i:i + 1])
        g2s = apply_batch(g, single)
        uhl += int(jnp.sum(batch_search_improved(g, g2s, single, lab)))
        g, lab, _ = batchhl_update(g, single, lab)
    return basic, improved, uhl


def run(datasets=DATASETS) -> list[str]:
    rows = []
    for ds in datasets:
        inst = cm.build_instance(ds)
        for mode in MODES:
            ups = cm.update_stream(inst.edges, inst.n, BATCH, mode, seed=11)
            b = make_batch(ups, pad_to=BATCH)
            g2 = apply_batch(inst.g, b)
            basic = int(jnp.sum(batch_search_basic(inst.g, g2, b, inst.lab)))
            improved = int(jnp.sum(
                batch_search_improved(inst.g, g2, b, inst.lab)))
            rows.append(cm.emit(
                f"table5/{ds}/{mode}", 0.0,
                f"BHL={basic},BHL+={improved},"
                f"prune_ratio={basic / max(improved, 1):.2f}"))
    # Figure 2: affected counts vs batch size, including the UHL blowup
    inst = cm.build_instance("ba_2k")
    for size in FIG2_SIZES:
        ups = cm.update_stream(inst.edges, inst.n, size, "mixed", seed=13)
        basic, improved, uhl = _affected_counts(inst, ups, size)
        rows.append(cm.emit(
            f"fig2/ba_2k/batch{size}", 0.0,
            f"BHL={basic},BHL+={improved},UHL={uhl}"))
    return rows


if __name__ == "__main__":
    run()
