"""Jit'd public wrapper for the min-plus kernel with CPU fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.minplus import kernel, ref


def minplus_bound(s: jax.Array, h: jax.Array, t: jax.Array,
                  use_pallas: bool | None = None) -> jax.Array:
    """Eq.-3 upper bound for a query batch. S/T [B,R], H [R,R] int32 → [B].

    use_pallas=None auto-selects: the Pallas kernel on TPU, interpret-mode
    Pallas for small validation runs, and the jnp oracle otherwise.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas:
        interpret = jax.default_backend() != "tpu"
        return kernel.minplus_pallas(s.astype(jnp.int32),
                                     h.astype(jnp.int32),
                                     t.astype(jnp.int32),
                                     interpret=interpret)
    return ref.minplus_bound(s.astype(jnp.int32), h.astype(jnp.int32),
                             t.astype(jnp.int32))
