"""Relaxation-engine dispatch parity: jnp and Pallas backends must be
bit-identical on every sweep shape the system uses (DESIGN.md §3).

Deterministic (no hypothesis dependency — this file is the bare-checkout
coverage for the hot paths): random graphs across small V, V not divisible
by block_v, and sparse/dense regimes; the Pallas path runs interpret-mode
off-TPU, i.e. the same kernel that compiles on TPU.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.graphs import generators as gen
from repro.graphs.coo import (INF_D, apply_batch, from_edges, make_batch,
                              to_numpy_adj)
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.batch import (batch_repair, batch_search_basic,
                              batch_search_improved, batchhl_update,
                              batchhl_update_split, uhl_update)
from repro.core.engine import JNP_PLAN, RelaxEngine, RelaxPlan, relax_sweep
from repro.core.labelling import INF_KEY2, INF_KEY4
from repro.core.query import batched_query, bounded_bibfs
from repro.core import ref

# Heavy parity matrix (interpret-mode Pallas on every call-site): the fast
# CI job skips it; the full job and tier-1 run it all.
pytestmark = pytest.mark.slow

# (n, extra_edges, block_v): small-V, non-divisible-by-block, tiny-block.
SHAPES = [(9, 4, 8), (30, 15, 16), (57, 30, 16), (64, 40, 32)]


def _instance(seed: int, n: int, extra: int, r: int = 3):
    edges = gen.random_connected(n, extra_edges=extra, seed=seed)
    g = from_edges(n, edges, edges.shape[0] + 32)
    landmarks = select_landmarks_by_degree(g, r)
    lab = build_labelling(g, landmarks)
    return edges, g, landmarks, lab


def _plan(g, block_v) -> RelaxPlan:
    return RelaxEngine(backend="pallas", block_v=block_v).prepare(g)


# --- raw sweep primitive ----------------------------------------------------

@pytest.mark.parametrize("n,extra,bv", SHAPES)
@pytest.mark.parametrize("step,inf,clear", [
    (1, int(INF_D), 0),          # Algo-2 / BiBFS waves
    (2, int(INF_KEY2), 1),       # key2: construction / Algo-4 repair
    (4, int(INF_KEY4), 2),       # key4: Algo-3 improved search
])
def test_sweep_parity(n, extra, bv, step, inf, clear):
    edges, g, _, _ = _instance(n + extra, n, extra)
    plan = _plan(g, bv)
    rng = np.random.default_rng(n * 7 + step)
    keys = jnp.asarray(rng.integers(0, inf, n, endpoint=True)
                       .astype(np.int32))
    hub = jnp.asarray(rng.random(n) < 0.3)
    mask = jnp.asarray(rng.random(g.src.shape[0]) < 0.7) & g.valid
    want = relax_sweep(JNP_PLAN, g, keys, step, inf,
                       hub=hub, clear_bit=clear, edge_mask=mask)
    got = relax_sweep(plan, g, keys, step, inf,
                      hub=hub, clear_bit=clear, edge_mask=mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sweep_parity_vmapped_planes():
    """The hot paths vmap sweeps over landmark planes; parity must hold
    with keys, hub, and edge masks all batched."""
    n, extra, bv, r = 41, 25, 16, 4
    edges, g, _, _ = _instance(11, n, extra)
    plan = _plan(g, bv)
    rng = np.random.default_rng(11)
    keys = jnp.asarray(rng.integers(0, 200, (r, n)).astype(np.int32))
    hub = jnp.asarray(rng.random((r, n)) < 0.2)
    mask = jnp.asarray(rng.random((r, g.src.shape[0])) < 0.8) & g.valid

    def run(plan):
        return jax.vmap(
            lambda k, h, m: relax_sweep(plan, g, k, 2, jnp.int32(INF_KEY2),
                                        hub=h, clear_bit=1, edge_mask=m)
        )(keys, hub, mask)

    np.testing.assert_array_equal(np.asarray(run(plan)),
                                  np.asarray(run(JNP_PLAN)))


# --- the four sweep call-sites ---------------------------------------------

@pytest.mark.parametrize("n,extra,bv", SHAPES)
def test_search_and_repair_parity(n, extra, bv):
    edges, g, landmarks, lab = _instance(n, n, extra)
    ups = gen.random_batch_updates(edges, n, n_ins=3, n_del=3, seed=n + 1)
    batch = make_batch(ups, pad_to=6)
    g2 = apply_batch(g, batch)
    plan = _plan(g2, bv)

    aff_b_j = batch_search_basic(g, g2, batch, lab)
    aff_b_p = batch_search_basic(g, g2, batch, lab, plan)
    np.testing.assert_array_equal(np.asarray(aff_b_p), np.asarray(aff_b_j))

    aff_i_j = batch_search_improved(g, g2, batch, lab)
    aff_i_p = batch_search_improved(g, g2, batch, lab, plan)
    np.testing.assert_array_equal(np.asarray(aff_i_p), np.asarray(aff_i_j))

    lab_j = batch_repair(g2, aff_i_j, lab)
    lab_p = batch_repair(g2, aff_i_j, lab, plan)
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(np.asarray(getattr(lab_p, f)),
                                      np.asarray(getattr(lab_j, f)))


@pytest.mark.parametrize("n,extra,bv", SHAPES)
@pytest.mark.parametrize("improved", [False, True])
def test_batchhl_update_parity(n, extra, bv, improved):
    """End-to-end: identical aff sets, repaired labellings, and query
    answers on both backends."""
    edges, g, landmarks, lab = _instance(n * 2 + 1, n, extra)
    ups = gen.random_batch_updates(edges, n, n_ins=4, n_del=4, seed=n + 2)
    batch = make_batch(ups, pad_to=8)
    plan = _plan(apply_batch(g, batch), bv)

    gj, labj, affj = batchhl_update(g, batch, lab, improved=improved)
    gp, labp, affp = batchhl_update(g, batch, lab, improved=improved,
                                    plan=plan)
    np.testing.assert_array_equal(np.asarray(affp), np.asarray(affj))
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(np.asarray(getattr(labp, f)),
                                      np.asarray(getattr(labj, f)))

    rng = np.random.default_rng(n)
    qs = jnp.asarray(rng.integers(0, n, 12), jnp.int32)
    qt = jnp.asarray(rng.integers(0, n, 12), jnp.int32)
    dj = batched_query(gj, labj, qs, qt)
    dp = batched_query(gp, labp, qs, qt, plan=plan)
    np.testing.assert_array_equal(np.asarray(dp), np.asarray(dj))


def test_pallas_update_matches_oracle():
    """Not just parity: the Pallas path agrees with the from-scratch BFS
    oracle on the repaired labelling and on exact query answers."""
    n = 34
    edges, g, landmarks, lab = _instance(21, n, 17)
    ups = gen.random_batch_updates(edges, n, n_ins=3, n_del=3, seed=5)
    batch = make_batch(ups, pad_to=6)
    plan = _plan(apply_batch(g, batch), 16)
    g2, lab2, _ = batchhl_update(g, batch, lab, improved=True, plan=plan)

    adj2 = to_numpy_adj(g2)
    od, oh, ohw, omask = ref.minimal_labelling(
        adj2, n, [int(x) for x in np.asarray(landmarks)])
    jd = np.asarray(lab2.dist)
    for i in range(len(np.asarray(landmarks))):
        for v in range(n):
            want = od[i][v] if od[i][v] != ref.INF else int(INF_D)
            assert jd[i, v] == want, (i, v)

    rng = np.random.default_rng(3)
    qs = rng.integers(0, n, 16).astype(np.int32)
    qt = rng.integers(0, n, 16).astype(np.int32)
    got = np.asarray(batched_query(g2, lab2, jnp.asarray(qs),
                                   jnp.asarray(qt), plan=plan))
    for k in range(16):
        want = ref.pair_distance(adj2, n, int(qs[k]), int(qt[k]))
        want = 0 if qs[k] == qt[k] else want
        want = int(INF_D) if want == ref.INF else want
        assert got[k] == want


@pytest.mark.parametrize("n,extra,bv", SHAPES)
def test_bibfs_parity(n, extra, bv):
    edges, g, landmarks, lab = _instance(n + 5, n, extra)
    plan = _plan(g, bv)
    rng = np.random.default_rng(n)
    s = jnp.asarray(rng.integers(0, n, 10), jnp.int32)
    t = jnp.asarray(rng.integers(0, n, 10), jnp.int32)
    bound = jnp.full((10,), INF_D, jnp.int32)
    dj = bounded_bibfs(g, lab.landmarks, s, t, bound, 32)
    dp = bounded_bibfs(g, lab.landmarks, s, t, bound, 32, plan)
    np.testing.assert_array_equal(np.asarray(dp), np.asarray(dj))


@pytest.mark.parametrize("n,extra,bv", SHAPES)
def test_construction_parity(n, extra, bv):
    edges, g, landmarks, _ = _instance(n + 9, n, extra)
    plan = _plan(g, bv)
    lab_j = build_labelling(g, landmarks)
    lab_p = build_labelling(g, landmarks, plan=plan)
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(np.asarray(getattr(lab_p, f)),
                                      np.asarray(getattr(lab_j, f)))


def _assert_labelling_matches_oracle(g2, landmarks, lab2):
    """Repaired dist planes must equal the from-scratch BFS oracle's."""
    adj2 = to_numpy_adj(g2)
    n = g2.n
    od, _, _, _ = ref.minimal_labelling(
        adj2, n, [int(x) for x in np.asarray(landmarks)])
    jd = np.asarray(lab2.dist)
    for i in range(len(np.asarray(landmarks))):
        for v in range(n):
            want = od[i][v] if od[i][v] != ref.INF else int(INF_D)
            assert jd[i, v] == want, (i, v)


@pytest.mark.parametrize("variant", ["split", "unit"])
def test_split_and_unit_variants_parity(variant):
    """BHL^s and UHL+ take the engine (per-sub-batch tiling) — their
    results must match the jnp reference bit-for-bit on every labelling
    field AND the from-scratch BFS oracle on the final snapshot."""
    n = 28
    edges, g, landmarks, lab = _instance(13, n, 14)
    ups = gen.random_batch_updates(edges, n, n_ins=3, n_del=3, seed=17)
    batch = make_batch(ups, pad_to=6)
    engine = RelaxEngine(backend="pallas", block_v=16)
    update = batchhl_update_split if variant == "split" else uhl_update

    g_j, lab_j, aff_j = update(g, batch, lab)
    g_p, lab_p, aff_p = update(g, batch, lab, engine=engine)
    np.testing.assert_array_equal(np.asarray(aff_p), np.asarray(aff_j))
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(np.asarray(getattr(lab_p, f)),
                                      np.asarray(getattr(lab_j, f)))
    np.testing.assert_array_equal(np.asarray(g_p.valid),
                                  np.asarray(g_j.valid))
    # Oracle correctness (not just backend parity) for both variants, on
    # both backends (they were just asserted identical).
    _assert_labelling_matches_oracle(g_j, landmarks, lab_j)

    # ...and exact query answers from the engine-driven labelling.
    rng = np.random.default_rng(n)
    qs = rng.integers(0, n, 12).astype(np.int32)
    qt = rng.integers(0, n, 12).astype(np.int32)
    plan = engine.prepare(g_p, topology_changed=False)
    got = np.asarray(batched_query(g_p, lab_p, jnp.asarray(qs),
                                   jnp.asarray(qt), plan=plan))
    adj2 = to_numpy_adj(g_j)
    for k in range(12):
        want = ref.pair_distance(adj2, n, int(qs[k]), int(qt[k]))
        want = 0 if qs[k] == qt[k] else want
        want = int(INF_D) if want == ref.INF else want
        assert got[k] == want


# --- tiling-cache contract --------------------------------------------------

def test_engine_retile_cache():
    """Deletion-only ticks reuse the tiling; insertions force a rebuild;
    the jnp backend never tiles (no host syncs)."""
    n = 26
    edges, g, landmarks, lab = _instance(19, n, 13)
    engine = RelaxEngine(backend="pallas", block_v=16)
    plan0 = engine.prepare(g)
    assert engine.retile_count == 1

    # deletion-only: cache hit, tiles object unchanged
    dele = make_batch([(int(edges[0][0]), int(edges[0][1]), True)], pad_to=1)
    g2 = apply_batch(g, dele)
    plan1 = engine.prepare(g2, topology_changed=False)
    assert engine.retile_count == 1
    assert plan1.tiles is plan0.tiles
    # ...and the reused tiling still gives correct (jnp-identical) results
    gj, labj, affj = batchhl_update(g, dele, lab)
    gp, labp, affp = batchhl_update(g, dele, lab, plan=plan1)
    np.testing.assert_array_equal(np.asarray(affp), np.asarray(affj))
    np.testing.assert_array_equal(np.asarray(labp.dist),
                                  np.asarray(labj.dist))

    # insertion: topology slots rewritten → retile
    ins = make_batch([(0, n - 1, False)], pad_to=1)
    g3 = apply_batch(g2, ins)
    plan2 = engine.prepare(g3, topology_changed=True)
    assert engine.retile_count == 2
    assert plan2.tiles is not plan0.tiles

    jnp_engine = RelaxEngine(backend="jnp")
    assert jnp_engine.prepare(g).tiles is None
    assert jnp_engine.retile_count == 0


def test_engine_prepare_catches_stale_cache():
    """prepare(topology_changed=False) after slots actually changed (or on
    a different graph entirely) must retile, not silently serve stale
    tiles — the snapshot fingerprint recorded at tiling time catches it."""
    n = 26
    edges, g, landmarks, lab = _instance(19, n, 13)
    engine = RelaxEngine(backend="pallas", block_v=16)
    engine.prepare(g)
    assert engine.retile_count == 1

    # An insertion rewrites topology slots; the caller *lies* about it.
    ins = make_batch([(0, n - 1, False), (1, n - 2, False)], pad_to=2)
    g2 = apply_batch(g, ins)
    plan = engine.prepare(g2, topology_changed=False)
    assert engine.retile_count == 2, "stale tiling served for new topology"
    assert engine.stale_cache_retiles == 1
    # ...and the (re)tiled plan gives correct distances on the new graph.
    lab_j = build_labelling(g2, landmarks)
    lab_p = build_labelling(g2, landmarks, plan=plan)
    np.testing.assert_array_equal(np.asarray(lab_p.dist),
                                  np.asarray(lab_j.dist))

    # A different graph entirely (same n/capacity) also mismatches.
    other = gen.random_connected(n, extra_edges=13, seed=99)
    g_other = from_edges(n, other, g.capacity)
    engine.prepare(g_other, topology_changed=False)
    assert engine.stale_cache_retiles == 2

    # Legitimate deletion-only reuse still hits the cache.
    dele = make_batch([(int(other[0][0]), int(other[0][1]), True)], pad_to=1)
    engine.prepare(apply_batch(g_other, dele), topology_changed=False)
    assert engine.retile_count == 3  # unchanged by the deletion-only call
    assert engine.stale_cache_retiles == 2


def test_fingerprint_distinguishes_slot_layouts():
    """Regression (found by the batch-split property test): whole-batch
    vs split-batch application ends with the same edge multiset in
    *different slot layouts*. A slot-position-insensitive checksum keys
    them to the same cached tiling, whose embedded slot permutation then
    re-tiles the wrong graph's validity mask — distances go to INF. The
    fingerprint must differ whenever slot layout differs."""
    n, n_ins, n_del = 18, 3, 2
    edges = gen.random_connected(n, extra_edges=n // 2, seed=0)
    g = from_edges(n, edges, edges.shape[0] + 16)
    ups = gen.random_batch_updates(edges, n, n_ins=n_ins, n_del=n_del,
                                   seed=3)
    g_whole = apply_batch(g, make_batch(ups, pad_to=len(ups)))
    j = len(ups) // 2
    g_split = apply_batch(apply_batch(g, make_batch(ups[:j], pad_to=j)),
                          make_batch(ups[j:], pad_to=len(ups) - j))
    # Same edge set, different slot layout (the collision precondition).
    assert to_numpy_adj(g_whole) == to_numpy_adj(g_split)
    assert not np.array_equal(np.asarray(g_whole.src),
                              np.asarray(g_split.src))
    fp_w = RelaxEngine._snapshot_fingerprint(g_whole)
    fp_s = RelaxEngine._snapshot_fingerprint(g_split)
    assert fp_w != fp_s

    # Behavioral pin: preparing both layouts through ONE engine (shared
    # plan cache) must yield jnp-identical updates for each.
    landmarks = select_landmarks_by_degree(g, 3)
    lab = build_labelling(g, landmarks)
    engine = RelaxEngine(backend="pallas", block_v=16)
    batch_w = make_batch(ups, pad_to=len(ups))
    plan_w = engine.prepare(g_whole)
    ups_b = ups[j:]
    batch_a = make_batch(ups[:j], pad_to=j)
    g_a = apply_batch(g, batch_a)
    plan_a = engine.prepare(g_a)
    _, lab_a, _ = batchhl_update(g, batch_a, lab, plan=plan_a, g_new=g_a)
    plan_s = engine.prepare(g_split)
    batch_b = make_batch(ups_b, pad_to=len(ups_b))
    _, lab_s, _ = batchhl_update(g_a, batch_b, lab_a, plan=plan_s,
                                 g_new=g_split)
    _, lab_w, _ = batchhl_update(g, batch_w, lab, plan=plan_w,
                                 g_new=g_whole)
    np.testing.assert_array_equal(np.asarray(lab_s.dist),
                                  np.asarray(lab_w.dist))
    np.testing.assert_array_equal(np.asarray(lab_s.hub),
                                  np.asarray(lab_w.hub))


def test_engine_backend_validation():
    with pytest.raises(ValueError):
        RelaxEngine(backend="cuda")
    with pytest.raises(ValueError):
        RelaxEngine(backend="pallas", shards=0)
    edges, g, _, _ = _instance(2, 12, 6)
    bad = RelaxPlan(tiles=None, backend="nope")
    with pytest.raises(ValueError):
        relax_sweep(bad, g, jnp.zeros(12, jnp.int32), 1, int(INF_D))


def test_plan_survives_mesh_roundtrip():
    """Regression for the old `shard_gate` downgrade, which dropped the
    plan object entirely under a mesh: one prepared plan must serve a
    sharded update and then an unsharded call *without* retiling — the
    fingerprint check recognizes the (deletion-only) snapshot as the one
    it tiled."""
    from repro.core.shard import shard_batchhl_update
    from repro.launch.mesh import make_host_mesh

    n = 40
    edges, g, landmarks, lab = _instance(23, n, 20, r=8)
    engine = RelaxEngine(backend="pallas", block_v=16, shards=2)
    plan0 = engine.prepare(g)
    assert engine.retile_count == 1

    dele = make_batch([(int(edges[0][0]), int(edges[0][1]), True),
                       (int(edges[1][0]), int(edges[1][1]), True)], pad_to=2)
    mesh = make_host_mesh()
    sg, slab, saff = shard_batchhl_update(mesh, g, batch=dele, labelling=lab,
                                          plan=plan0)

    # Post-mesh, single-device: same tiles object, no retile, no stale
    # catch — the mesh leg never invalidated the cache.
    plan1 = engine.prepare(sg, topology_changed=False)
    assert plan1.tiles is plan0.tiles
    assert engine.retile_count == 1
    assert engine.stale_cache_retiles == 0
    gj, labj, affj = batchhl_update(g, dele, lab)
    gp, labp, affp = batchhl_update(g, dele, lab, plan=plan1)
    np.testing.assert_array_equal(np.asarray(affp), np.asarray(affj))
    np.testing.assert_array_equal(np.asarray(labp.dist),
                                  np.asarray(labj.dist))
    # ...and the sharded leg itself matched the unsharded jnp reference.
    np.testing.assert_array_equal(np.asarray(saff), np.asarray(affj))
    np.testing.assert_array_equal(np.asarray(slab.dist),
                                  np.asarray(labj.dist))


# --- three-way backend × mesh parity sweep ---------------------------------

@pytest.mark.parametrize("mode", ["insert", "delete", "mixed"])
def test_three_way_backend_mesh_parity(mode):
    """sharded-pallas ≡ sharded-jnp ≡ unsharded-jnp, bit-for-bit, on
    insert-only, delete-only, and mixed batches — labelling fields,
    affected sets, and query answers."""
    from repro.core.shard import shard_batched_query, shard_batchhl_update
    from repro.launch.mesh import make_host_mesh

    n = 48
    edges, g, landmarks, lab = _instance(29, n, 30, r=8)
    n_ins, n_del = {"insert": (5, 0), "delete": (0, 5),
                    "mixed": (3, 3)}[mode]
    ups = gen.random_batch_updates(edges, n, n_ins=n_ins, n_del=n_del,
                                   seed=37)
    batch = make_batch(ups, pad_to=max(n_ins + n_del, 1))
    g_next = apply_batch(g, batch)
    plan = RelaxEngine(backend="pallas", block_v=16, shards=2).prepare(g_next)
    mesh = make_host_mesh()

    g_u, lab_u, aff_u = batchhl_update(g, batch, lab, improved=True)
    g_sj, lab_sj, aff_sj = shard_batchhl_update(mesh, g, batch, lab,
                                                g_new=g_next)
    g_sp, lab_sp, aff_sp = shard_batchhl_update(mesh, g, batch, lab,
                                                plan=plan, g_new=g_next)

    for name, aff, labx in (("sharded-jnp", aff_sj, lab_sj),
                            ("sharded-pallas", aff_sp, lab_sp)):
        np.testing.assert_array_equal(np.asarray(aff), np.asarray(aff_u),
                                      err_msg=name)
        for f in ("dist", "hub", "highway"):
            np.testing.assert_array_equal(np.asarray(getattr(labx, f)),
                                          np.asarray(getattr(lab_u, f)),
                                          err_msg=f"{name}.{f}")

    rng = np.random.default_rng(n)
    qs = jnp.asarray(rng.integers(0, n, 19), jnp.int32)
    qt = jnp.asarray(rng.integers(0, n, 19), jnp.int32)
    d_u = batched_query(g_u, lab_u, qs, qt)
    d_sj = shard_batched_query(mesh, g_sj, lab_sj, qs, qt)
    d_sp = shard_batched_query(mesh, g_sp, lab_sp, qs, qt,
                               use_kernel=True, plan=plan)
    np.testing.assert_array_equal(np.asarray(d_sj), np.asarray(d_u))
    np.testing.assert_array_equal(np.asarray(d_sp), np.asarray(d_u))
