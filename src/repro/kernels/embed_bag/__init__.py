from repro.kernels.embed_bag import kernel, ops, ref  # noqa: F401
