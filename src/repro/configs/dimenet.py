"""dimenet [gnn]: n_blocks=6 d_hidden=128 n_bilinear=8 n_spherical=7
n_radial=6 [arXiv:2003.03123; unverified]."""
from repro.models.gnn import GNNConfig

ARCH_ID = "dimenet"
FAMILY = "gnn"
SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")


def model_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID, arch="dimenet", d_in=16, d_hidden=128,
                     d_out=1, n_blocks=6, n_bilinear=8, n_spherical=7,
                     n_radial=6, cutoff=10.0)


def reduced_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID + "-smoke", arch="dimenet", d_in=8,
                     d_hidden=16, d_out=1, n_blocks=2, n_bilinear=4,
                     n_spherical=3, n_radial=4, cutoff=10.0)
