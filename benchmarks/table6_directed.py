"""Paper Table 6: directed graphs — update/construction/query time and
labelling size for the two-plane (forward+backward) BatchHL."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs.coo import make_batch
from repro.core.directed import (from_arcs, build_directed_labelling,
                                 batchhl_update_directed, directed_query)
from benchmarks import common as cm

BATCH = 128
N_QUERIES = 256


def _digraph(n, m, seed=0):
    rng = np.random.default_rng(seed)
    arcs = set()
    for v in range(1, n):
        u = int(rng.integers(v))
        arcs.add((u, v) if rng.random() < 0.7 else (v, u))
    while len(arcs) < m:
        u, v = int(rng.integers(n)), int(rng.integers(n))
        if u != v:
            arcs.add((u, v))
    return np.asarray(sorted(arcs), np.int32)


def run() -> list[str]:
    rows = []
    rng = np.random.default_rng(1)
    for name, n, m in (("digraph_2k", 2000, 8000),
                       ("digraph_8k", 8000, 32000)):
        arcs = _digraph(n, m)
        g = from_arcs(n, arcs, arcs.shape[0] + 2 * BATCH)
        deg = np.zeros(n)
        for u, v in arcs:
            deg[u] += 1
            deg[v] += 1
        landmarks = jnp.asarray(np.argsort(-deg)[:16].astype(np.int32))
        t0 = time.time()
        lab = build_directed_labelling(g, landmarks)
        jax.block_until_ready(lab.fwd.dist)
        rows.append(cm.emit(f"table6/{name}/construction",
                            time.time() - t0, f"V={n},A={m}"))
        size = int(lab.fwd.label_size()) + int(lab.bwd.label_size())
        rows.append(cm.emit(f"table6/{name}/label_size", 0.0,
                            f"entries={size},per_vertex={size / n:.2f}"))

        existing = {(int(u), int(v)) for u, v in arcs}
        ups = []
        picks = rng.choice(len(arcs), size=BATCH // 2, replace=False)
        ups += [(int(arcs[i, 0]), int(arcs[i, 1]), True) for i in picks]
        while sum(1 for x in ups if not x[2]) < BATCH // 2:
            u, v = int(rng.integers(n)), int(rng.integers(n))
            if u != v and (u, v) not in existing:
                existing.add((u, v))
                ups.append((u, v, False))
        batch = make_batch(ups, pad_to=BATCH)
        t_u = cm.timeit(lambda: batchhl_update_directed(g, batch, lab))
        rows.append(cm.emit(f"table6/{name}/update_BHL+", t_u,
                            f"batch={BATCH}"))

        qs = jnp.asarray(rng.integers(0, n, N_QUERIES), jnp.int32)
        qt = jnp.asarray(rng.integers(0, n, N_QUERIES), jnp.int32)
        t_q = cm.timeit(lambda: directed_query(g, lab, qs, qt))
        rows.append(cm.emit(f"table6/{name}/query", t_q / N_QUERIES,
                            f"batch={N_QUERIES}"))
    return rows


if __name__ == "__main__":
    run()
