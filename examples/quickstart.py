"""Quickstart: build a highway-cover labelling, apply a batch update,
answer exact distance queries — the paper's pipeline in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.graphs import generators as gen
from repro.graphs.coo import from_edges, make_batch
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.batch import batchhl_update
from repro.core.query import batched_query

# 1. a small-diameter complex network (Barabási–Albert, like the paper's)
n = 5_000
edges = gen.barabasi_albert(n, 4, seed=0)
g = from_edges(n, edges, capacity=edges.shape[0] + 256)

# 2. offline: pick high-degree landmarks, build the minimal labelling
landmarks = select_landmarks_by_degree(g, k=16)
lab = build_labelling(g, landmarks)
print(f"labelling built: {int(lab.label_size())} entries "
      f"({int(lab.label_size()) / n:.2f} per vertex, R=16)")

# 3. online: a mixed batch of edge insertions + deletions (BatchHL)
updates = gen.random_batch_updates(edges, n, n_ins=50, n_del=50, seed=1)
batch = make_batch(updates, pad_to=100)
g, lab, affected = batchhl_update(g, batch, lab, improved=True)
print(f"batch of 100 updates applied; "
      f"{int(jnp.sum(affected))} (landmark, vertex) pairs affected")

# 4. answer exact distance queries on the updated graph
rng = np.random.default_rng(0)
s = jnp.asarray(rng.integers(0, n, 8), jnp.int32)
t = jnp.asarray(rng.integers(0, n, 8), jnp.int32)
dist = batched_query(g, lab, s, t)
for i in range(8):
    d = int(dist[i])
    print(f"d({int(s[i])}, {int(t[i])}) = {'inf' if d > n else d}")
