"""Property-based metamorphic suite for served distances and updates.

Invariant families, each pinned on BOTH engine backends (the jnp
segment-min reference and the interpret-mode Pallas kernel):

  * metric laws of served distances — symmetry d(s,t) = d(t,s) and the
    triangle inequality d(s,t) <= d(s,u) + d(u,t);
  * insert∘delete round-trip — updating with a batch of fresh edges and
    then deleting them restores the labelling bit-for-bit (the labelling
    is canonical per graph, so round-tripping the graph round-trips it);
  * batch-split invariance — one batch applied whole equals the same
    updates applied as two sequential chunks (bit-equal planes);
  * the weighted metric (DESIGN.md §8) — served distances on weighted
    graphs equal the host Dijkstra oracle exactly (plus the metric laws),
    weight-change ∘ weight-restore round-trips the labelling bit-for-bit,
    and batch-split invariance holds for batches that mix insert/delete
    with re-weight ops.

Unlike the slow-marked oracle suites, this module is sized for the fast
CI job (`-m "not slow"`): tiny graphs, few examples — the point is the
metamorphic relations, which need no oracle and catch a different class
of bug (asymmetric state, slot-layout leakage into answers, batch-size
dependence) than pointwise BFS checks do.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # optional dep; bare checkouts skip
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.graphs import generators as gen
from repro.graphs.coo import (apply_batch, from_edges, make_batch,
                              to_numpy_adj, to_numpy_wadj)
from repro.core import ref
from repro.core.batch import batchhl_update
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.engine import RelaxEngine
from repro.core.query import batched_query

SETTINGS = dict(deadline=None, max_examples=8,
                suppress_health_check=[HealthCheck.too_slow,
                                       HealthCheck.differing_executors])
BACKENDS = ("jnp", "pallas")


def _engine(backend: str) -> RelaxEngine | None:
    return None if backend == "jnp" else RelaxEngine(backend="pallas",
                                                     block_v=16)


def _build(n: int, seed: int, backend: str, slack: int = 16):
    edges = gen.random_connected(n, extra_edges=n // 2, seed=seed)
    g = from_edges(n, edges, edges.shape[0] + slack)
    landmarks = select_landmarks_by_degree(g, 3)
    engine = _engine(backend)
    plan = engine.prepare(g) if engine else None
    lab = build_labelling(g, landmarks, plan=plan)
    return g, lab, edges, engine, plan


def _update(g, lab, ups, engine, pad_to=None):
    """One engine-routed BatchHL tick (plan prepared post-update)."""
    batch = make_batch(ups, pad_to=pad_to or max(len(ups), 1))
    if not ups:  # all-padding batch: a no-op update
        batch = dataclasses.replace(batch,
                                    valid=jnp.zeros_like(batch.valid))
    g_next = apply_batch(g, batch)
    plan = engine.prepare(g_next) if engine else None
    g2, lab2, _ = batchhl_update(g, batch, lab, plan=plan, g_new=g_next)
    return g2, lab2, plan


def _assert_labellings_equal(a, b):
    for f in ("dist", "hub", "highway"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)))


# --- metric laws of served distances ---------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 26))
def test_served_distances_symmetric_and_triangle(backend, seed, n):
    g, lab, _, _, plan = _build(n, seed, backend)
    rng = np.random.default_rng(seed + 1)
    s, t, u = (jnp.asarray(rng.integers(0, n, 16), jnp.int32)
               for _ in range(3))
    d_st = np.asarray(batched_query(g, lab, s, t, plan=plan), np.int64)
    d_ts = np.asarray(batched_query(g, lab, t, s, plan=plan), np.int64)
    np.testing.assert_array_equal(d_st, d_ts)
    d_su = np.asarray(batched_query(g, lab, s, u, plan=plan), np.int64)
    d_ut = np.asarray(batched_query(g, lab, u, t, plan=plan), np.int64)
    assert np.all(d_st <= d_su + d_ut), (d_st, d_su, d_ut)


# --- insert∘delete round-trip ----------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 24),
       k=st.integers(1, 5))
def test_insert_then_delete_restores_labelling(backend, seed, n, k):
    g, lab0, edges, engine, _ = _build(n, seed, backend)
    rng = np.random.default_rng(seed + 2)
    existing = {(min(int(u), int(v)), max(int(u), int(v))) for u, v in edges}
    fresh = []
    while len(fresh) < k:
        u, v = int(rng.integers(n)), int(rng.integers(n))
        key = (min(u, v), max(u, v))
        if u != v and key not in existing:
            existing.add(key)
            fresh.append((u, v))
    g1, lab1, _ = _update(g, lab0, [(u, v, False) for u, v in fresh], engine)
    g2, lab2, _ = _update(g1, lab1, [(u, v, True) for u, v in fresh], engine)
    assert to_numpy_adj(g2) == to_numpy_adj(g)
    # The labelling is canonical per graph: round-tripping the edge set
    # round-trips every plane bit-for-bit (== the fresh construction).
    _assert_labellings_equal(lab2, lab0)


# --- batch-split invariance ------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 24),
       n_ins=st.integers(1, 4), n_del=st.integers(0, 3))
def test_batch_split_invariance(backend, seed, n, n_ins, n_del):
    g, lab0, edges, engine, _ = _build(n, seed, backend)
    ups = gen.random_batch_updates(edges, n, n_ins=n_ins, n_del=n_del,
                                   seed=seed + 3)
    g_whole, lab_whole, _ = _update(g, lab0, ups, engine)
    j = len(ups) // 2
    g_a, lab_a, _ = _update(g, lab0, ups[:j], engine)
    g_b, lab_b, _ = _update(g_a, lab_a, ups[j:], engine)
    assert to_numpy_adj(g_b) == to_numpy_adj(g_whole)
    _assert_labellings_equal(lab_b, lab_whole)


# --- weighted metric (DESIGN.md §8) ----------------------------------------

def _build_weighted(n: int, seed: int, backend: str, max_w: int = 8,
                    slack: int = 16):
    edges = gen.random_connected(n, extra_edges=n // 2, seed=seed)
    rng = np.random.default_rng(seed + 7)
    w = rng.integers(1, max_w + 1, size=edges.shape[0])
    ew = np.concatenate([edges, w[:, None]], axis=1).astype(np.int32)
    g = from_edges(n, ew, edges.shape[0] + slack)
    landmarks = select_landmarks_by_degree(g, 3)
    engine = _engine(backend)
    plan = engine.prepare(g) if engine else None
    lab = build_labelling(g, landmarks, plan=plan)
    return g, lab, ew, engine, plan


@pytest.mark.parametrize("backend", BACKENDS)
@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 22),
       max_w=st.integers(2, 9))
def test_weighted_distances_match_dijkstra(backend, seed, n, max_w):
    """Served distances on a weighted graph are Dijkstra-exact, symmetric,
    and satisfy the triangle inequality."""
    g, lab, _, _, plan = _build_weighted(n, seed, backend, max_w)
    wadj = to_numpy_wadj(g)
    rng = np.random.default_rng(seed + 1)
    s, t, u = (jnp.asarray(rng.integers(0, n, 12), jnp.int32)
               for _ in range(3))
    d_st = np.asarray(batched_query(g, lab, s, t, plan=plan), np.int64)
    for i in range(12):
        want = ref.pair_distance_w(wadj, n, int(s[i]), int(t[i]))
        got = float(d_st[i])
        assert (got == want) or (want == ref.INF and got >= 1 << 28), \
            (int(s[i]), int(t[i]), got, want)
    d_ts = np.asarray(batched_query(g, lab, t, s, plan=plan), np.int64)
    np.testing.assert_array_equal(d_st, d_ts)
    d_su = np.asarray(batched_query(g, lab, s, u, plan=plan), np.int64)
    d_ut = np.asarray(batched_query(g, lab, u, t, plan=plan), np.int64)
    assert np.all(d_st <= d_su.astype(np.int64) + d_ut)


@pytest.mark.parametrize("backend", BACKENDS)
@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 22),
       k=st.integers(1, 4))
def test_weight_change_then_restore_roundtrips(backend, seed, n, k):
    """Re-weighting k edges and then restoring their original weights
    returns the labelling bit-for-bit — and the intermediate labelling
    equals fresh construction on the re-weighted graph."""
    g, lab0, ew, engine, _ = _build_weighted(n, seed, backend)
    rng = np.random.default_rng(seed + 11)
    idx = rng.choice(ew.shape[0], size=min(k, ew.shape[0]), replace=False)
    spike = [(int(ew[i, 0]), int(ew[i, 1]), 2, int(ew[i, 2]) + 3)
             for i in idx]
    restore = [(int(ew[i, 0]), int(ew[i, 1]), 2, int(ew[i, 2]))
               for i in idx]
    g1, lab1, plan1 = _update(g, lab0, spike, engine)
    lab1_fresh = build_labelling(g1, lab0.landmarks, plan=plan1)
    _assert_labellings_equal(lab1, lab1_fresh)
    g2, lab2, _ = _update(g1, lab1, restore, engine)
    assert to_numpy_wadj(g2) == to_numpy_wadj(g)
    _assert_labellings_equal(lab2, lab0)


@pytest.mark.parametrize("backend", BACKENDS)
@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), n=st.integers(8, 22),
       n_ins=st.integers(1, 3), n_del=st.integers(0, 2),
       n_rew=st.integers(1, 3))
def test_weighted_batch_split_invariance(backend, seed, n, n_ins, n_del,
                                         n_rew):
    """Whole-batch ≡ split-batch for batches mixing insert/delete with
    re-weight ops on a weighted graph (bit-equal planes and weights)."""
    g, lab0, ew, engine, _ = _build_weighted(n, seed, backend)
    ups = gen.random_batch_updates(ew, n, n_ins=n_ins, n_del=n_del,
                                   seed=seed + 3, n_rew=n_rew, max_weight=6)
    g_whole, lab_whole, _ = _update(g, lab0, ups, engine)
    j = len(ups) // 2
    g_a, lab_a, _ = _update(g, lab0, ups[:j], engine)
    g_b, lab_b, _ = _update(g_a, lab_a, ups[j:], engine)
    assert to_numpy_wadj(g_b) == to_numpy_wadj(g_whole)
    _assert_labellings_equal(lab_b, lab_whole)
