"""Masked segment-op wrappers: the message-passing substrate.

The GNN models route through these directly. BatchHL's relaxation sweeps
route through `core/engine.py`, whose jnp backend lowers to
`masked_segment_min` here and whose pallas backend dispatches to the tiled
`kernels.edge_relax` kernel — one seam for every sweep (DESIGN.md §3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_segment_min(data: jax.Array, segment_ids: jax.Array,
                       num_segments: int, mask: jax.Array,
                       fill: jax.Array) -> jax.Array:
    """segment_min over masked entries; empty segments get `fill`."""
    data = jnp.where(mask, data, fill)
    out = jax.ops.segment_min(data, segment_ids, num_segments=num_segments)
    return jnp.minimum(out, fill)  # clamp +inf sentinels from empty segments


def masked_segment_sum(data: jax.Array, segment_ids: jax.Array,
                       num_segments: int, mask: jax.Array) -> jax.Array:
    if mask is not None:
        zero = jnp.zeros((), data.dtype)
        data = jnp.where(
            mask.reshape(mask.shape + (1,) * (data.ndim - mask.ndim)),
            data, zero)
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def masked_segment_max(data: jax.Array, segment_ids: jax.Array,
                       num_segments: int, mask: jax.Array,
                       fill: jax.Array) -> jax.Array:
    data = jnp.where(mask, data, fill)
    out = jax.ops.segment_max(data, segment_ids, num_segments=num_segments)
    return jnp.maximum(out, fill)


def masked_segment_mean(data: jax.Array, segment_ids: jax.Array,
                        num_segments: int, mask: jax.Array) -> jax.Array:
    s = masked_segment_sum(data, segment_ids, num_segments, mask)
    cnt = jax.ops.segment_sum(mask.astype(data.dtype), segment_ids,
                              num_segments=num_segments)
    cnt = jnp.maximum(cnt, 1)
    return s / cnt.reshape(cnt.shape + (1,) * (s.ndim - cnt.ndim))


def edge_relax_sweep(keys: jax.Array, src: jax.Array, dst: jax.Array,
                     edge_mask: jax.Array, step: jax.Array | int,
                     n: int, inf: jax.Array) -> jax.Array:
    """One relaxation wave: cand[v] = min over valid edges (u,v) of keys[u]+step.

    Kept as the minimal reference form of the sweep; the BatchHL hot paths
    now call `core.engine.relax_sweep`, which generalizes this with the
    hub bit-clear extension and backend dispatch. `keys` may be [V] or
    batched [..., V] (vmapped by callers).
    """
    gathered = keys[src]
    cand = jnp.minimum(gathered + step, inf)
    return masked_segment_min(cand, dst, n, edge_mask, inf)
