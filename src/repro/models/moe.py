"""Capacity-based top-k MoE FFN (GShard/Mixtral/DeepSeek style).

Tokens are processed in fixed-size *groups* (GShard's dispatch groups): the
one-hot dispatch/combine tensors are [G, tg, E, Cg] with per-group capacity
Cg = tg·k·cf/E, so dispatch memory is linear in the token count
(t · k · cf · tg elements total) instead of quadratic — the difference
between 63 MB and 64 GB per device at the deepseek prefill_32k shape.

Groups shard over the mesh `data` axis, experts over `model`; the dispatch
einsum then induces the canonical all-to-all. Overflow beyond Cg is dropped
(capacity_factor 1.25), the standard trade; the shared-expert/residual path
carries dropped tokens.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_GROUP = 512  # dispatch group size (tokens)


def _act(h, kind):
    if kind == "silu":
        return jax.nn.silu(h)
    if kind == "gelu":
        return jax.nn.gelu(h)
    r = jax.nn.relu(h)
    return r * r


def moe_ffn(p: dict, x: jax.Array, c) -> jax.Array:
    """x [B, S, D] → [B, S, D] through routed experts."""
    b, s, d = x.shape
    t = b * s
    e = c.n_experts
    tg = min(_GROUP, t)
    g = t // tg
    assert t % tg == 0, (t, tg)
    xt = x.reshape(g, tg, d)

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, top_idx = jax.lax.top_k(probs, c.top_k)       # [g, tg, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    cap = max(int(tg * c.top_k / e * c.capacity_factor), 4)

    # Position of each (token, k) within its expert's per-group capacity.
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.int32)     # [g, tg, k, e]
    flat = onehot.reshape(g, tg * c.top_k, e)
    pos = (jnp.cumsum(flat, axis=1) - 1) * flat              # [g, tg*k, e]
    pos = pos.reshape(g, tg, c.top_k, e)
    within = pos < cap

    disp = (jax.nn.one_hot(jnp.where(within, pos, cap), cap, dtype=x.dtype)
            * onehot.astype(x.dtype)[..., None])             # [g,tg,k,e,cap]
    dispatch = jnp.sum(disp, axis=2)                         # [g,tg,e,cap]
    combine = jnp.sum(disp * gate_vals.astype(x.dtype)[..., None, None],
                      axis=2)                                # [g,tg,e,cap]

    xe = jnp.einsum("gtd,gtec->gecd", xt, dispatch,
                    preferred_element_type=jnp.float32).astype(x.dtype)

    gt = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    if c.gated:
        up = jnp.einsum("gecd,edf->gecf", xe, p["w_up"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
        h = _act(gt, c.act) * up
    else:
        h = _act(gt, c.act)
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"],
                    preferred_element_type=jnp.float32).astype(x.dtype)

    yt = jnp.einsum("gecd,gtec->gtd", ye, combine,
                    preferred_element_type=jnp.float32).astype(x.dtype)
    return yt.reshape(b, s, d)


def load_balance_loss(logits: jax.Array, top_idx: jax.Array,
                      n_experts: int) -> jax.Array:
    """Switch-style auxiliary loss (exposed for training drivers)."""
    probs = jax.nn.softmax(logits.reshape(-1, n_experts), axis=-1)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_idx.reshape(-1, top_idx.shape[-1])[:, 0],
                                 n_experts, dtype=jnp.float32), axis=0)
    return n_experts * jnp.sum(me * ce)
