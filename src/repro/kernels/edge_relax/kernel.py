"""Blocked edge-relaxation kernel: the BatchHL wave hot loop.

    cand[v] = min over edges (u, v)   extend(keys[u])         (then min w/ keys)

where extend is the paper's path-extension operator on encoded keys
(see core/labelling.py): add `step`, clamp at `inf`, and clear `clear_bit`
when the destination is a landmark hub. With clear_bit=0 this degenerates to
plain min-plus relaxation (BFS / Algo-2 waves); with (step=2, clear_bit=1)
it is key2_extend (construction / Algo-4 repair) and with (step=4,
clear_bit=2) it is key4_extend (Algo-3 improved search).

TPU adaptation of the paper's adjacency-list traversal: edges are pre-tiled
by destination block (CSR-style reordering done once per graph topology,
amortized over all waves of all batches), so each grid step owns a disjoint
slice of the output vertices — no cross-block write races, no atomics.
Within a block the kernel gathers source keys from the VMEM-resident key
plane (per-device vertex shard: V_local ≤ ~1M keys = 4 MB, fits VMEM) and
scatter-mins into the local [BV] output tile. The per-edge validity mask is
re-derived on device every sweep (validity churns with every batch update),
while the src/dstloc tiling itself is rebuilt only when topology slots
change — the contract `core/engine.py` enforces.

The tiling is *shard-aware*: tile arrays carry a leading vertex-shard axis
[S, NB, BE] (S contiguous block_v-aligned slices of the vertex range, each
with its own destination blocks and its own slice of the slot permutation)
and the launch grid is (S, NB). Destination blocks never straddle a shard
boundary, so the per-block edge groups — and therefore the per-block
min-reductions — are identical for every S: results are bit-identical to
the S=1 tiling, which is the degenerate single-shard case. This is what
lets the kernel run inside `shard_map` bodies (`core/shard.py`) and, at
scale, lets each mesh device launch over its local slice only.

Working set per grid step: keys (full shard) + BE·3·4 B edge slice +
2·BV·4 B hub/out tiles. For BV=512, BE=4096: ≈ 64 KB on top of the keys.

This kernel regime is the sparse/SpMM family (kernel_taxonomy §B.3/§B.11):
gather → elementwise → segment-reduce. The MXU is idle; the roofline is
HBM-bandwidth on the edge slices + VMEM gather throughput.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

INF32 = 1 << 29  # plain int: pallas kernels must not capture traced constants


def _relax_kernel(keys_ref, src_ref, dstloc_ref, valid_ref, step_ref, o_ref):
    keys = keys_ref[...]          # [V] int32 (full shard)
    src = src_ref[0, 0]           # [BE]
    dstloc = dstloc_ref[0, 0]     # [BE] local dst in [0, BV)
    valid = valid_ref[0, 0]       # [BE] int32 mask
    step = step_ref[0]

    gathered = jnp.take(keys, src, axis=0)
    s = gathered + step
    cand = jnp.minimum(jnp.where(s < 0, INF32, s), INF32)
    cand = jnp.where(valid != 0, cand, INF32)
    out = jnp.full((o_ref.shape[-1],), INF32, jnp.int32)
    out = out.at[dstloc].min(cand)
    o_ref[...] = out[None, None, :]


def _relax_sweep_kernel(keys_ref, hub_ref, src_ref, dstloc_ref, mask_ref,
                        w_ref, params_ref, o_ref):
    """Generalized sweep: weighted extend (step·w / saturate-at-inf /
    hub bit-clear) + mask."""
    keys = keys_ref[...]          # [V] int32 (full shard)
    hub = hub_ref[0, 0]           # [BV] int32: dst-block hub flags
    src = src_ref[0, 0]           # [BE]
    dstloc = dstloc_ref[0, 0]     # [BE] local dst in [0, BV)
    mask = mask_ref[0, 0]         # [BE] int32: per-sweep edge validity
    w = w_ref[0, 0]               # [BE] int32: per-sweep edge weight
    step = params_ref[0]
    inf = params_ref[1]
    clear = params_ref[2]

    gathered = jnp.take(keys, src, axis=0)
    # Saturating weighted extend: keys and step·w are both non-negative
    # (step ≤ 4, w ≤ INF_D keeps the product in range), so the int32 sum
    # overflows iff it wraps negative — clamp those to inf rather than
    # letting a near-inf key pass a max-weight edge as a small key.
    s = gathered + step * w
    cand = jnp.minimum(jnp.where(s < 0, inf, s), inf)
    hub_e = jnp.take(hub, dstloc, axis=0)
    cand = jnp.where(hub_e != 0, cand & ~clear, cand)
    cand = jnp.where(mask != 0, cand, inf)
    out = jnp.full((o_ref.shape[-1],), inf, jnp.int32)
    out = out.at[dstloc].min(cand)
    o_ref[...] = out[None, None, :]


def block_edges_topology(src: np.ndarray, dst: np.ndarray, keep: np.ndarray,
                         n: int, block_v: int, block_e: int | None = None):
    """Host-side tiling: group the kept edge slots by destination block.

    Returns (src_t [NR, BE], dstloc_t [NR, BE], perm_t [NR, BE],
    slot_t [NR, BE], rowblk [NR], block_v). `perm_t` maps each tile slot
    back to its original edge index so per-sweep masks (validity churn,
    repair boundary/interior masks) can be re-tiled on device with one
    gather; `slot_t` is 0 on padding slots. Done once per graph topology.

    Without `block_e`, BE is the largest per-block edge count and NR = NB:
    one tile row per destination block (`rowblk` is the identity). On
    power-law graphs that single hub block inflates every row, so a tuned
    `block_e` caps BE and *chunks* oversized blocks into ceil(count/BE)
    rows — `rowblk[r]` names the destination block row r feeds, rows of
    one block are consecutive, and total padding is bounded by NB·BE
    instead of NB·max-degree-block. Every block keeps at least one row
    (possibly all-padding) so reducing rows by `rowblk` yields a value
    for every block.
    """
    keep = np.asarray(keep, bool)
    idx = np.flatnonzero(keep).astype(np.int64)
    src_k, dst_k = src[idx], dst[idx]
    nb = -(-n // block_v)
    order = np.argsort(dst_k // block_v, kind="stable")
    src_k, dst_k, idx = src_k[order], dst_k[order], idx[order]
    counts = np.bincount(dst_k // block_v, minlength=nb)
    be = block_e or max(int(counts.max() if counts.size else 0), 8)
    rows_per_block = np.maximum(-(-counts // be), 1)
    nr = int(rows_per_block.sum())
    src_t = np.zeros((nr, be), np.int32)
    dst_t = np.zeros((nr, be), np.int32)
    perm_t = np.zeros((nr, be), np.int32)
    slot_t = np.zeros((nr, be), np.int32)
    rowblk = np.repeat(np.arange(nb, dtype=np.int32),
                       rows_per_block).astype(np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)])
    row_starts = np.concatenate([[0], np.cumsum(rows_per_block)])
    if src_k.size:
        # Each kept edge lands at (row_starts[block] + within // BE,
        # within % BE) where `within` is its rank inside its block —
        # one vectorized scatter (this runs every insert tick on the
        # serving path, so no per-block python loop).
        blk = dst_k // block_v
        within = np.arange(src_k.size, dtype=np.int64) - starts[blk]
        r = row_starts[blk] + within // be
        c = within % be
        src_t[r, c] = src_k
        dst_t[r, c] = dst_k - blk * block_v
        perm_t[r, c] = idx
        slot_t[r, c] = 1
    return src_t, dst_t, perm_t, slot_t, rowblk, block_v


def aligned_vertex_count(n: int, block_v: int, shards: int) -> int:
    """Smallest vertex count >= n that tiles cleanly: a multiple of
    block_v · shards, so every destination block is full-width and
    `shard_tiling` splits the block axis into `shards` equal groups with
    no all-padding blocks. The growth policy (`core/growth.py`) rounds
    grown vertex counts up to this so a grown tiling has the same shape
    invariants as a fresh one at the same size.
    """
    if n < 1 or block_v < 1 or shards < 1:
        raise ValueError(
            f"need positive n/block_v/shards, got {n}/{block_v}/{shards}")
    unit = block_v * shards
    return -(-n // unit) * unit


def shard_tiling(shards: int, nb: int, rowblk: np.ndarray,
                 *tiles: np.ndarray):
    """Split [NR, BE] tile rows into `shards` contiguous vertex shards.

    Shard s owns destination blocks [s·NB_loc, (s+1)·NB_loc) — and every
    tile row feeding them. Block boundaries are block_v-aligned, so no
    destination block straddles a shard, row *contents* are untouched, and
    flattening the per-shard block order recovers the exact unsharded
    order (padding blocks all land past the last real block, past every
    real vertex). Per-block reductions — and therefore sweep results —
    are bit-identical for every S.

    Returns (rowblk_t [S, NR_loc] of *local* block ids, nb_loc,
    *tiles [S, NR_loc, BE]). Shards with fewer rows pad with all-zero
    rows mapped to the shard's last local block (keeps each shard's
    rowblk sorted — the row→block reduction relies on it); padding rows
    have slot_t=0 everywhere, so they only contribute `inf`.
    """
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    nb_loc = max(-(-nb // shards), 1)
    shard_of = rowblk // nb_loc                       # rows sorted by block,
    row_counts = np.bincount(shard_of, minlength=shards)  # so shards are
    nr_loc = max(int(row_counts.max()), 1)                # contiguous runs
    row_starts = np.concatenate([[0], np.cumsum(row_counts)])
    be = tiles[0].shape[1]
    rowblk_t = np.full((shards, nr_loc), nb_loc - 1, np.int32)
    out = [np.zeros((shards, nr_loc, be), t.dtype) for t in tiles]
    for s in range(shards):
        lo, hi = int(row_starts[s]), int(row_starts[s + 1])
        m = hi - lo
        rowblk_t[s, :m] = rowblk[lo:hi] - s * nb_loc
        for o, t in zip(out, tiles):
            o[s, :m] = t[lo:hi]
    return (rowblk_t, nb_loc) + tuple(out)


def _reduce_rows(out: jax.Array, rowblk_t: jax.Array | None, nb: int | None,
                 inf) -> jax.Array:
    """Fold per-row partial mins [S, NR, BV] into per-block mins [S, NB, BV].

    Rows of one destination block are consecutive and each block has at
    least one row, so a sorted segment-min per shard recovers exactly the
    per-block reduction an unchunked tiling computes — min-of-mins over
    any grouping of the same integer multiset. `rowblk_t=None` means the
    tiling was not chunked (NR = NB, identity mapping): pass through.
    Padding blocks (no rows at all only happens past `nb`) clamp to `inf`.
    """
    if rowblk_t is None:
        return out
    def one(o, rb):
        return jax.ops.segment_min(o, rb, num_segments=nb,
                                   indices_are_sorted=True)
    return jnp.minimum(jax.vmap(one)(out, rowblk_t), inf)


@functools.partial(jax.jit, static_argnames=("n", "block_v", "nb",
                                             "interpret"))
def edge_relax_pallas(keys: jax.Array, src_t: jax.Array, dstloc_t: jax.Array,
                      valid_t: jax.Array, step: jax.Array, n: int,
                      block_v: int, interpret: bool = True,
                      rowblk_t: jax.Array | None = None,
                      nb: int | None = None) -> jax.Array:
    """keys [V] int32 + tiled edges [S, NR, BE] → cand [V] int32.

    `rowblk_t`/`nb` describe a block_e-chunked tiling (see
    `block_edges_topology`); omitted, rows are blocks (NR = NB).
    """
    s, nr, be = src_t.shape
    step_arr = jnp.full((1,), step, jnp.int32)

    out = pl.pallas_call(
        _relax_kernel,
        grid=(s, nr),
        in_specs=[
            pl.BlockSpec(keys.shape, lambda j, i: (0,) * keys.ndim),
            pl.BlockSpec((1, 1, be), lambda j, i: (j, i, 0)),
            pl.BlockSpec((1, 1, be), lambda j, i: (j, i, 0)),
            pl.BlockSpec((1, 1, be), lambda j, i: (j, i, 0)),
            pl.BlockSpec((1,), lambda j, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_v), lambda j, i: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, nr, block_v), jnp.int32),
        interpret=interpret,
    )(keys, src_t, dstloc_t, valid_t, step_arr)
    out = _reduce_rows(out, rowblk_t, nb, INF32)
    return out.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("n", "block_v", "nb",
                                             "interpret"))
def relax_sweep_pallas(keys: jax.Array, hub_t: jax.Array, src_t: jax.Array,
                       dstloc_t: jax.Array, mask_t: jax.Array,
                       w_t: jax.Array,
                       step: jax.Array, inf: jax.Array, clear_bit: jax.Array,
                       n: int, block_v: int, interpret: bool = True,
                       rowblk_t: jax.Array | None = None,
                       nb: int | None = None) -> jax.Array:
    """Generalized sweep: keys [V] + per-row hub tiles [S, NR, BV] + tiled
    edges/weights [S, NR, BE] → [V].

    cand[v] = min over masked edges (u, v) of
        clear_hub_bit_if_hub(v, sat(keys[u] + step·w(u,v), inf));
    `inf` if none. The add saturates at `inf` (int32 wrap → inf).
    The grid walks (vertex shard, tile row); each step owns one disjoint
    [BV] output tile, so S is a pure launch-structure knob. With a
    block_e-chunked tiling (`rowblk_t`/`nb` set) several rows feed one
    destination block and a sorted segment-min folds the per-row partials
    — bit-identical to the unchunked reduction (min-of-mins).
    """
    s, nr, be = src_t.shape
    params = jnp.stack([jnp.asarray(step, jnp.int32),
                        jnp.asarray(inf, jnp.int32),
                        jnp.asarray(clear_bit, jnp.int32)])

    out = pl.pallas_call(
        _relax_sweep_kernel,
        grid=(s, nr),
        in_specs=[
            pl.BlockSpec(keys.shape, lambda j, i: (0,) * keys.ndim),
            pl.BlockSpec((1, 1, block_v), lambda j, i: (j, i, 0)),
            pl.BlockSpec((1, 1, be), lambda j, i: (j, i, 0)),
            pl.BlockSpec((1, 1, be), lambda j, i: (j, i, 0)),
            pl.BlockSpec((1, 1, be), lambda j, i: (j, i, 0)),
            pl.BlockSpec((1, 1, be), lambda j, i: (j, i, 0)),
            pl.BlockSpec((3,), lambda j, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_v), lambda j, i: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, nr, block_v), jnp.int32),
        interpret=interpret,
    )(keys, hub_t, src_t, dstloc_t, mask_t, w_t, params)
    out = _reduce_rows(out, rowblk_t, nb, jnp.asarray(inf, jnp.int32))
    return out.reshape(-1)[:n]
