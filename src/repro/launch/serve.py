"""BatchHL distance-query serving driver — the paper's system end-to-end.

    PYTHONPATH=src python -m repro.launch.serve --n 2000 --batches 5

Per tick the loop ingests one batch of edge updates (mix set by
``--scenario``), maintains the labelling with BatchHL, and answers an
*open-loop* query stream: ``--queries`` arrivals per tick at Poisson rate
``--qps``, dispatched in microbatches of ``--microbatch``. Two serving
modes (DESIGN.md §5):

* **synchronous** (default): one monolithic `batchhl_update` dispatch per
  tick. Every query that arrives while it runs queues behind it on the
  device, so tail latency is bounded below by update time — the failure
  mode BatchHL exists to avoid.

* **``--pipeline``**: the update runs as *bounded chunks*
  (`core/snapshot.pipelined_update`, ``--chunk-sweeps`` relaxation waves
  per dispatch) against snapshot N+1 while query microbatches keep
  dispatching against the immutable committed snapshot N; the commit is
  an atomic version swap. A query waits for at most one chunk instead of
  the whole update, answers stay exact at the version they were served
  (staleness ≤ 1 version, reported), and the final labelling is
  bit-identical to the synchronous loop's.

The loop reports p50/p95/p99 query latency and answer staleness per run;
``--verify`` checks every sampled answer against a BFS oracle *at the
version it was answered* — stale answers are exact too.

Sweep backend: ``--backend {auto,jnp,pallas}`` selects the relaxation
engine backend (DESIGN.md §3). The loop owns one `RelaxEngine`, whose
fingerprint-keyed plan cache keeps both live snapshots' tilings (the
committed one serving queries and the post-update one under repair).

Mesh sharding: ``--mesh host`` runs construction, updates, and queries
through `core/shard.py` on a `make_host_mesh` over the local devices;
``--shards M`` sets the model-axis size. Landmark counts are validated
against *both* plane groupings (data·model for maintenance, model for
queries) with an error naming the failing grouping. Backend × mesh
compose as before; in pipeline mode the maintenance chunks use the
data×model plane grouping while interleaved query microbatches regroup
over model — overlapped on the device queue instead of serialized.

Checkpointing: ``--ckpt-dir`` persists the *full* serve state each tick
(graph topology + labelling + version + the host edge list);
``--resume`` restarts from the newest checkpoint and continues the
exact stream (seeds are tick-indexed).

Grow-in-place: ``--capacity C`` starts the run at C edge slots instead
of provisioning the scenario's worst case; with ``--grow`` a batch that
would overflow (or that introduces vertex ids ≥ n) grows the slot
arrays and labelling planes geometrically to the next aligned size at
the version boundary — queries keep serving the committed pre-growth
snapshot throughout, and the post-growth labelling is bit-identical to
fresh construction at the grown size (DESIGN.md §6). Without ``--grow``
an overflow raises a typed ``CapacityError`` naming the tick and the
required sizes before anything is dispatched.
"""
from __future__ import annotations

import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs import generators as gen
from repro.graphs.coo import (apply_batch, from_edges, make_batch,
                              to_numpy_wadj)
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.batch import batchhl_update
from repro.core.engine import RelaxEngine
from repro.core.query import batched_query
from repro.core.shard import (shard_batched_query, shard_batchhl_update,
                              shard_build_labelling,
                              validate_landmark_sharding)
from repro.core.growth import GrowthEvent, GrowthPolicy, ensure_capacity
from repro.core.snapshot import (Snapshot, SnapshotStore, pipelined_update,
                                 restore_extra, restore_snapshot,
                                 save_snapshot)
from repro.core import ref
from repro.checkpoint import manager as ckpt
from repro.data.scenarios import get_scenario
from repro.launch.mesh import make_host_mesh


@dataclasses.dataclass
class ServeConfig:
    """Everything the serving loop needs; `main()` maps CLI flags here."""
    n: int = 2000
    deg: int = 4
    #: initial graph family: "ba" (power-law, unit weights) or "road"
    #: (weighted planar grid, DESIGN.md §8). Road rounds n up to the grid
    #: size rows·cols at loop construction.
    graph: str = "ba"
    landmarks: int = 16
    batches: int = 5
    batch_size: int = 100
    scenario: str = "mixed"
    # open-loop query stream
    queries: int = 256          # arrivals per tick
    qps: float = 2000.0         # Poisson arrival rate (queries/second)
    microbatch: int = 32        # max queries per dispatched microbatch
    # serving mode
    pipeline: bool = False
    chunk_sweeps: int = 1       # relaxation waves per pipelined dispatch
    # engine / mesh
    backend: str = "auto"
    block_v: int = 512
    tile_shards: int = 1
    block_e: int | None = None   # tile-row width cap of the pallas tiling
    use_minplus_kernel: bool = False
    mesh: str = "none"
    shards: int = 1
    # autotuning + fusion (DESIGN.md §7)
    autotune: bool = False       # measure & adopt the fastest sweep impl
                                 # per snapshot shape (core/autotune.py)
    tune_table: str | None = None  # on-disk tuning table; restarts skip
                                   # the measurement entirely
    fused: bool = False          # pipelined chunks as fused megakernel
                                 # dispatches with donated planes
    # frontier-proportional sweeps (DESIGN.md §10)
    frontier: bool = False       # relax only the tile rows the change
                                 # frontier touches (masked sweeps)
    frontier_threshold: float = 0.25  # density fallback: max fraction of
                                      # tile rows a masked wave may gather
    # capacity / grow-in-place (DESIGN.md §6)
    capacity: int | None = None  # initial edge capacity (None = provision
                                 # for the scenario's worst-case inserts)
    grow: bool = False           # grow slots/planes geometrically on
                                 # overflow instead of raising CapacityError
    growth_factor: float = 2.0
    # ops
    verify: bool = False
    ckpt_dir: str | None = None
    resume: bool = False
    seed: int = 7
    quiet: bool = False
    #: retain every committed snapshot in the report (tests/verification:
    #: lets a caller recompute any answer synchronously at its version)
    keep_history: bool = False


@dataclasses.dataclass
class MicrobatchRecord:
    """One answered microbatch: which queries, at which version."""
    tick: int
    version: int                # snapshot version the answers are exact at
    staleness: int              # versions behind the in-flight head
    qs: np.ndarray              # int32 [m] (unpadded)
    qt: np.ndarray
    answers: np.ndarray         # int32 [m]
    latencies: np.ndarray       # float64 [m] seconds, arrival → answered


@dataclasses.dataclass
class TickStats:
    tick: int
    version: int                # committed version after this tick
    update_s: float             # dispatch start → commit
    affected: int
    label_size: int
    queries: int
    verify_mismatches: int | None = None
    grew: bool = False          # this tick grew capacity/planes (§6)
    capacity: int = 0           # edge capacity after this tick
    graph_n: int = 0            # vertex slots after this tick


@dataclasses.dataclass
class ServeReport:
    """Everything a caller (benchmarks, tests) needs from one run."""
    config: ServeConfig
    ticks: list[TickStats]
    microbatches: list[MicrobatchRecord]
    final: Snapshot
    backend: str
    #: version -> committed Snapshot, populated when keep_history is set
    history: dict[int, Snapshot] = dataclasses.field(default_factory=dict)
    #: grow-in-place events, in tick order (empty without --grow)
    growth: list[GrowthEvent] = dataclasses.field(default_factory=list)

    def latencies(self) -> np.ndarray:
        if not self.microbatches:
            return np.zeros((0,))
        return np.concatenate([m.latencies for m in self.microbatches])

    def latency_percentiles(self) -> dict[str, float]:
        lat = self.latencies()
        if lat.size == 0:
            return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
        return {p: float(np.percentile(lat, q))
                for p, q in (("p50", 50), ("p95", 95), ("p99", 99))}

    def staleness(self) -> np.ndarray:
        return np.concatenate(
            [np.full(m.latencies.shape, m.staleness, np.int32)
             for m in self.microbatches]) if self.microbatches else \
            np.zeros((0,), np.int32)

    def mean_staleness(self) -> float:
        s = self.staleness()
        return float(s.mean()) if s.size else 0.0


class ServeLoop:
    """The serving pipeline: one instance owns the engine, the snapshot
    store, the scenario streams, and the open-loop query clock."""

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        #: optional process hooks (launch/replica.py): `on_start(snap0)`
        #: fires once the initial snapshot is in the store, before any
        #: tick; `on_commit(tick, snap)` fires after each tick's commit
        #: and checkpoint — the replica updater publishes + runs the
        #: reader ack barrier there (DESIGN.md §9).
        self.on_start = None
        self.on_commit = None
        self.scenario = get_scenario(cfg.scenario)
        if cfg.graph not in ("ba", "road"):
            raise ValueError(f"unknown graph family {cfg.graph!r}; "
                             f"choose 'ba' or 'road'")
        if cfg.graph == "road":
            # The grid generator realizes rows·cols >= n vertices; the
            # whole loop (queries, update sampling, landmarks) must agree
            # on the realized count.
            rows = max(2, int(math.isqrt(cfg.n)))
            cols = max(2, (cfg.n + rows - 1) // rows)
            cfg.n = rows * cols
        self.mesh = None
        if cfg.mesh == "host":
            self.mesh = make_host_mesh(model=cfg.shards)
            validate_landmark_sharding(self.mesh, cfg.landmarks)
        self.engine = RelaxEngine(backend=cfg.backend, block_v=cfg.block_v,
                                  shards=cfg.tile_shards,
                                  block_e=cfg.block_e,
                                  autotune=cfg.autotune,
                                  tune_table=cfg.tune_table,
                                  frontier=cfg.frontier,
                                  frontier_threshold=cfg.frontier_threshold)
        self.store: SnapshotStore | None = None
        self.report: ServeReport | None = None
        # host-side current edge set, maintained incrementally: a
        # swap-remove list + position map keeps each tick O(batch); the
        # *order* is serve state (deletion sampling depends on it), so it
        # rides along in every checkpoint, together with the per-edge
        # weights (the serve-side mirror of the graph's w column).
        self._edge_list: list[tuple[int, int]] = []
        self._edge_pos: dict[tuple[int, int], int] = {}
        self._edge_w: dict[tuple[int, int], int] = {}
        self._oracle_adj: dict[int, dict] = {}  # version -> adjacency

    @property
    def growth_policy(self) -> GrowthPolicy:
        """Grow-in-place policy, aligned to the engine's *current* tiling
        unit (engine.plan_alignment = block_v · shards) so grown and fresh
        tilings share shape invariants, backend-independent. A property —
        not frozen at construction — because adopting an autotuned
        kernel-impl winner updates the engine's block_v, and grown vertex
        counts must respect the alignment of the tiles actually served."""
        return GrowthPolicy(factor=self.cfg.growth_factor,
                            block_v=self.engine.block_v,
                            shards=self.engine.shards)

    def _log(self, msg: str) -> None:
        if not self.cfg.quiet:
            print(msg, flush=True)

    # -- setup --------------------------------------------------------------

    def _fresh_snapshot(self) -> Snapshot:
        cfg = self.cfg
        if cfg.graph == "road":
            edges = gen.road_grid(cfg.n, max_weight=max(
                2, self.scenario.max_weight), seed=0)
        else:
            edges = gen.barabasi_albert(cfg.n, cfg.deg, seed=0)
        # Explicit --capacity starts the run at that size (the grow-in-place
        # entry point: pair with --grow to start small and let the stream
        # grow the slots); the default provisions the scenario's worst case
        # up front, as before.
        cap = cfg.capacity if cfg.capacity is not None else (
            edges.shape[0]
            + self.scenario.max_inserts(cfg.batches, cfg.batch_size) + 64)
        g = from_edges(cfg.n, edges, cap)
        landmarks = select_landmarks_by_degree(g, cfg.landmarks)
        plan = self.engine.prepare(g)
        t0 = time.time()
        if self.mesh is not None:
            lab = shard_build_labelling(self.mesh, g, landmarks, plan=plan)
        else:
            lab = build_labelling(g, landmarks, plan=plan)
        jax.block_until_ready(lab.dist)
        self._edge_list = [(int(min(a, b)), int(max(a, b)))
                           for a, b in edges[:, :2]]
        self._edge_pos = {e: i for i, e in enumerate(self._edge_list)}
        self._edge_w = {e: (int(row[2]) if edges.shape[1] > 2 else 1)
                        for e, row in zip(self._edge_list, edges)}
        self._log(f"constructed labelling: {cfg.n} vertices, "
                  f"{edges.shape[0]} edges, R={cfg.landmarks}, "
                  f"size={int(lab.label_size())}, {time.time() - t0:.2f}s "
                  f"[backend={self.engine.backend}, {self._mesh_desc()}]")
        return Snapshot(0, g, lab, plan)

    def _resumed_snapshot(self) -> Snapshot:
        cfg = self.cfg
        snap = restore_snapshot(cfg.ckpt_dir)
        # A grown run checkpoints n >= cfg.n (growth only widens), so the
        # graph's own n cannot distinguish "this config, grown" from "a
        # different, larger config". Each checkpoint therefore carries the
        # run's *base* n; resuming requires it to match exactly. Pre-growth
        # checkpoints (no base_n leaf) never grew, so their graph n is the
        # base and the old exact check applies.
        try:
            base_n = int(restore_extra(cfg.ckpt_dir,
                                       ("base_n",))["base_n"])
        except FileNotFoundError:
            base_n = snap.graph.n
        if base_n != cfg.n:
            raise ValueError(
                f"checkpoint is from a run with n={base_n} "
                f"(grown to {snap.graph.n}), config has n={cfg.n}")
        edge_arr = restore_extra(cfg.ckpt_dir, ("edge_list",))["edge_list"]
        self._edge_list = [(int(r[0]), int(r[1])) for r in edge_arr]
        self._edge_pos = {e: i for i, e in enumerate(self._edge_list)}
        self._edge_w = {e: (int(r[2]) if edge_arr.shape[1] > 2 else 1)
                        for e, r in zip(self._edge_list, edge_arr)}
        snap = dataclasses.replace(snap, plan=self.engine.prepare(snap.graph))
        self._log(f"resumed at version {snap.version}: {cfg.n} vertices, "
                  f"{len(self._edge_list)} edges, "
                  f"size={int(snap.labelling.label_size())} "
                  f"[backend={self.engine.backend}, {self._mesh_desc()}]")
        return snap

    def _mesh_desc(self) -> str:
        if self.mesh is None:
            return "unsharded"
        return (f"mesh data={self.mesh.shape['data']} "
                f"model={self.mesh.shape['model']}")

    # -- query stream -------------------------------------------------------

    def _tick_queries(self, tick: int) -> tuple[np.ndarray, np.ndarray,
                                                np.ndarray]:
        """This tick's open-loop stream: (offsets [Q] s, qs [Q], qt [Q]).

        Content and arrival offsets are pure functions of (seed, tick), so
        sync and pipelined runs — and a resumed run — see the identical
        stream; only *when* each query is answered differs.
        """
        cfg = self.cfg
        arr_rng = np.random.default_rng((cfg.seed, 101, tick))
        offsets = np.cumsum(
            arr_rng.exponential(1.0 / cfg.qps, size=cfg.queries))
        q_rng = np.random.default_rng((cfg.seed, 202, tick))
        qs, qt = self.scenario.sample_queries(q_rng, cfg.n, cfg.queries)
        return offsets, qs, qt

    def _answer(self, snap: Snapshot, qs: jax.Array,
                qt: jax.Array) -> jax.Array:
        if self.mesh is None:
            d = batched_query(snap.graph, snap.labelling, qs, qt,
                              use_kernel=self.cfg.use_minplus_kernel,
                              plan=snap.plan)
        else:
            d = shard_batched_query(self.mesh, snap.graph, snap.labelling,
                                    qs, qt,
                                    use_kernel=self.cfg.use_minplus_kernel,
                                    plan=snap.plan)
        jax.block_until_ready(d)
        return d

    def _drain_arrived(self, tick: int, tick_t0: float, offsets: np.ndarray,
                       qs: np.ndarray, qt: np.ndarray, served: int,
                       head_version: int,
                       out: list[MicrobatchRecord]) -> int:
        """Answer every query that has arrived by now, in microbatches of
        at most cfg.microbatch, against the committed snapshot. Returns
        the new served count."""
        cfg = self.cfg
        q = offsets.shape[0]
        while served < q:
            arrived = int(np.searchsorted(offsets, time.time() - tick_t0,
                                          side="right"))
            if arrived <= served:
                break
            take = min(cfg.microbatch, arrived - served)
            idx = np.arange(served, served + take)
            # Pad to the fixed microbatch shape (one compile) by repeating
            # the first query; the pad lanes are dropped from the record.
            pad_idx = np.concatenate(
                [idx, np.full(cfg.microbatch - take, idx[0])])
            snap = self.store.committed
            d = self._answer(snap, jnp.asarray(qs[pad_idx]),
                             jnp.asarray(qt[pad_idx]))
            t_done = time.time()
            out.append(MicrobatchRecord(
                tick=tick, version=snap.version,
                staleness=head_version - snap.version,
                qs=qs[idx].copy(), qt=qt[idx].copy(),
                answers=np.asarray(d)[:take].copy(),
                latencies=t_done - (tick_t0 + offsets[idx])))
            served += take
        return served

    def _drain_rest(self, tick: int, tick_t0: float, offsets: np.ndarray,
                    qs: np.ndarray, qt: np.ndarray, served: int,
                    head_version: int, out: list[MicrobatchRecord]) -> int:
        """Serve the tick's remaining arrivals, sleeping the open-loop
        clock forward between stragglers."""
        q = offsets.shape[0]
        while served < q:
            wait = tick_t0 + offsets[served] - time.time()
            if wait > 0:
                time.sleep(wait)
            served = self._drain_arrived(tick, tick_t0, offsets, qs, qt,
                                         served, head_version, out)
        return served

    # -- update modes -------------------------------------------------------

    def _update_sync(self, snap: Snapshot, batch, plan, g_next) -> Snapshot:
        """The monolithic update: one dispatch, queries queue behind it."""
        if self.mesh is None:
            g2, lab2, aff = batchhl_update(snap.graph, batch, snap.labelling,
                                           improved=True, plan=plan,
                                           g_new=g_next)
        else:
            g2, lab2, aff = shard_batchhl_update(self.mesh, snap.graph,
                                                 batch, snap.labelling,
                                                 improved=True, plan=plan,
                                                 g_new=g_next)
        jax.block_until_ready(lab2.dist)
        self._last_aff = aff
        return Snapshot(snap.version + 1, g2, lab2, plan)

    def _update_pipelined(self, snap: Snapshot, batch, plan, g_next,
                          tick: int, tick_t0: float, offsets, qs, qt,
                          served_box: list, out) -> Snapshot:
        """The chunked update: serve arrived microbatches at every yield."""
        cfg = self.cfg
        upd = pipelined_update(snap, batch, plan=plan, g_new=g_next,
                               mesh=self.mesh, improved=True,
                               chunk_sweeps=cfg.chunk_sweeps,
                               fused=cfg.fused)
        head = snap.version + 1
        while True:
            try:
                next(upd)
            except StopIteration as stop:
                nxt, aff = stop.value
                break
            served_box[0] = self._drain_arrived(
                tick, tick_t0, offsets, qs, qt, served_box[0], head, out)
        jax.block_until_ready(nxt.labelling.dist)
        self._last_aff = aff
        return nxt

    # -- verification -------------------------------------------------------

    def _oracle(self, version: int, graph) -> dict:
        if version not in self._oracle_adj:
            self._oracle_adj[version] = to_numpy_wadj(graph)
            # A tick only ever verifies against its own two versions;
            # evict older adjacencies so --verify stays O(E) host memory
            # on long runs instead of O(ticks × E).
            for old in [v for v in self._oracle_adj if v < version - 1]:
                del self._oracle_adj[old]
        return self._oracle_adj[version]

    def _verify_tick(self, tick: int, out: list[MicrobatchRecord],
                     snapshots: dict[int, Snapshot]) -> int:
        """Check the first min(64, Q) answered queries of the tick against
        the Dijkstra oracle *at the version each was answered* — the
        staleness contract says stale answers are exact at their own
        version (for w ≡ 1 graphs the oracle degenerates to BFS)."""
        n_check = min(64, self.cfg.queries)
        wrong = checked = 0
        for m in out:
            if m.tick != tick or checked >= n_check:
                continue
            adj = self._oracle(m.version, snapshots[m.version].graph)
            for i in range(m.qs.shape[0]):
                if checked >= n_check:
                    break
                got = float(m.answers[i])
                # len(adj) is the snapshot's own n — a grown snapshot has
                # more vertices than cfg.n, and the search must see them
                # all.
                want = ref.pair_distance_w(adj, len(adj), int(m.qs[i]),
                                           int(m.qt[i]))
                want = got if (want == ref.INF and got >= 1e8) else want
                if int(m.qs[i]) == int(m.qt[i]):
                    want = 0
                wrong += int(got != want)
                checked += 1
        self._log(f"  verify: {wrong}/{n_check} mismatches")
        return wrong

    # -- the loop -----------------------------------------------------------

    def run(self) -> ServeReport:
        cfg = self.cfg
        resumable = (cfg.resume and cfg.ckpt_dir
                     and ckpt.latest_step(cfg.ckpt_dir) is not None)
        snap0 = self._resumed_snapshot() if resumable \
            else self._fresh_snapshot()
        self.store = SnapshotStore(snap0)
        if self.on_start is not None:
            self.on_start(snap0)
        ticks: list[TickStats] = []
        out: list[MicrobatchRecord] = []
        growth: list[GrowthEvent] = []
        history: dict[int, Snapshot] = {}
        if cfg.keep_history:
            history[snap0.version] = snap0
        self._last_aff = None

        for tick in range(snap0.version, cfg.batches):
            snap = self.store.committed
            n_ins, n_del, n_rew = self.scenario.update_counts(
                tick, cfg.batch_size)
            cur_edges = np.asarray(self._edge_list, np.int32)
            ups = gen.random_batch_updates(
                cur_edges, cfg.n, n_ins=n_ins, n_del=n_del,
                seed=100 + tick, existing=self._edge_pos, n_rew=n_rew,
                max_weight=self.scenario.max_weight)
            batch = make_batch(ups, pad_to=cfg.batch_size)
            offsets, qs, qt = self._tick_queries(tick)
            # Insert ops alone move topology slots; deletions flip
            # validity in place and reweights touch only the w column,
            # so a reweight-only tick reuses the committed tiling.
            has_ins = any(not int(up[2]) for up in ups)

            # Grow-in-place check *before* any dispatch (DESIGN.md §6): an
            # overflowing batch grows the working snapshot — same version,
            # larger slots/planes — or raises a typed CapacityError naming
            # this tick. The committed snapshot keeps serving queries
            # untouched either way; the grown shapes first become visible
            # to readers at the next commit's pointer swap.
            work, event = ensure_capacity(snap, batch, self.growth_policy,
                                          grow=cfg.grow, tick=tick)
            if event is not None:
                growth.append(event)
                self._log(f"  grow: capacity {event.old_capacity}->"
                          f"{event.new_capacity}, n {event.old_n}->"
                          f"{event.new_n} (needed {event.required_capacity}"
                          f"/{event.required_n})")

            served_box = [0]
            tick_t0 = time.time()
            # One tiling per tick, prepared from the post-update snapshot
            # (the engine contract); the keyed plan cache keeps the
            # committed snapshot's tiling alive alongside it. Growth moved
            # topology slots (capacity/n changed → new fingerprint), so it
            # forces a clean retile exactly like an insertion does.
            g_next = apply_batch(work.graph, batch)
            plan = self.engine.prepare(
                g_next, topology_changed=has_ins or event is not None)
            if cfg.pipeline:
                nxt = self._update_pipelined(work, batch, plan, g_next,
                                             tick, tick_t0, offsets, qs, qt,
                                             served_box, out)
            else:
                nxt = self._update_sync(work, batch, plan, g_next)
            t_upd = time.time() - tick_t0
            self.store.commit(nxt)
            if cfg.keep_history:
                history[nxt.version] = nxt
            served_box[0] = self._drain_rest(
                tick, tick_t0, offsets, qs, qt, served_box[0],
                nxt.version, out)

            # Fold the tick's updates into the incremental edge set
            # (op 0 = insert, 1 = delete, 2 = reweight).
            for up in ups:
                u, v, op = up[0], up[1], int(up[2])
                w = int(up[3]) if len(up) > 3 else 1
                k = (min(u, v), max(u, v))
                if op == 1:
                    i = self._edge_pos.pop(k, None)
                    if i is not None:
                        self._edge_w.pop(k, None)
                        last = self._edge_list.pop()
                        if i < len(self._edge_list):
                            self._edge_list[i] = last
                            self._edge_pos[last] = i
                elif op == 2:
                    if k in self._edge_pos:
                        self._edge_w[k] = w
                elif k not in self._edge_pos:
                    self._edge_pos[k] = len(self._edge_list)
                    self._edge_list.append(k)
                    self._edge_w[k] = w

            tick_mbs = [m for m in out if m.tick == tick]
            lat = (np.concatenate([m.latencies for m in tick_mbs])
                   if tick_mbs else np.zeros((1,)))
            stale = sum(int(m.staleness > 0) * m.qs.shape[0]
                        for m in tick_mbs)
            stats = TickStats(
                tick=tick, version=nxt.version, update_s=t_upd,
                affected=int(jnp.sum(self._last_aff)),
                label_size=int(nxt.labelling.label_size()),
                queries=int(served_box[0]),
                grew=event is not None,
                capacity=nxt.graph.capacity, graph_n=nxt.graph.n)
            self._log(
                f"tick {tick}: update {t_upd * 1e3:.1f}ms "
                f"({stats.affected} affected, v{nxt.version}) | "
                f"{stats.queries} queries p50 "
                f"{np.percentile(lat, 50) * 1e3:.1f}ms p99 "
                f"{np.percentile(lat, 99) * 1e3:.1f}ms "
                f"({stale} stale) | label size {stats.label_size}")

            if cfg.verify:
                snapshots = {snap.version: snap, nxt.version: nxt}
                stats.verify_mismatches = self._verify_tick(
                    tick, tick_mbs, snapshots)
            ticks.append(stats)

            if cfg.ckpt_dir:
                edge_rows = np.asarray(
                    [(u, v, self._edge_w.get((u, v), 1))
                     for u, v in self._edge_list],
                    np.int32).reshape(-1, 3)
                save_snapshot(
                    cfg.ckpt_dir, nxt,
                    extra={"edge_list": edge_rows,
                           "base_n": np.int64(cfg.n)})
            if self.on_commit is not None:
                self.on_commit(tick, nxt)

        self.report = ServeReport(config=cfg, ticks=ticks, microbatches=out,
                                  final=self.store.committed,
                                  backend=self.engine.backend,
                                  history=history, growth=growth)
        pct = self.report.latency_percentiles()
        mode = "pipeline" if cfg.pipeline else "sync"
        engine = self.engine
        engine_desc = (
            "" if engine.backend == "jnp" else
            f"retiles={engine.retile_count}/{cfg.batches + 1} prepares, "
            f"{engine.plan_cache_hits} plan-cache hits, "
            f"{engine.stale_cache_retiles} stale-cache catches, "
            f"tile-shards={engine.shards}, ")
        self._log(
            f"latency: p50 {pct['p50'] * 1e3:.1f}ms "
            f"p95 {pct['p95'] * 1e3:.1f}ms p99 {pct['p99'] * 1e3:.1f}ms | "
            f"staleness mean {self.report.mean_staleness():.2f} versions "
            f"behind head [{mode}, chunk-sweeps={cfg.chunk_sweeps}, "
            f"scenario={cfg.scenario}]")
        if growth:
            final_g = self.store.committed.graph
            self._log(f"grew {len(growth)}x: capacity "
                      f"{growth[0].old_capacity}->{final_g.capacity}, "
                      f"n {growth[0].old_n}->{final_g.n} "
                      f"[factor={cfg.growth_factor:g}, "
                      f"v-align={engine.plan_alignment}]")
        self._log(f"serve loop done [backend={engine.backend}, "
                  f"{engine_desc}{self._mesh_desc()}, mode={mode}]")
        return self.report


def main() -> None:
    # The parser is generated from the composable spec dataclasses
    # (launch/config.py) — one source of truth shared with the replica
    # roles; `--config <spec.json>` launches from a serialized ServeSpec
    # and flat flags remain as the (warned) legacy override surface.
    from repro.launch import config as cfgmod

    ap = cfgmod.build_parser(__doc__.splitlines()[0])
    args = ap.parse_args()
    spec = cfgmod.spec_from_cli(args, ap)
    autotune = spec.engine.autotune or spec.engine.tune_table is not None
    cfg = spec.to_serve_config(autotune=autotune)
    try:
        # Config validation (mesh shape, landmark groupings, scenario,
        # backend) happens at construction; runtime errors inside run()
        # propagate with their tracebacks rather than masquerading as
        # CLI misuse.
        loop = ServeLoop(cfg)
    except ValueError as e:
        ap.error(str(e))
    report = loop.run()
    if cfg.verify:
        bad = sum(t.verify_mismatches or 0 for t in report.ticks)
        if bad:
            raise SystemExit(f"verify FAILED: {bad} mismatched answers")


if __name__ == "__main__":
    main()
