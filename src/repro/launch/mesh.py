"""Production mesh construction.

A function (not a module-level constant) so importing never touches jax
device state; the dry-run entrypoint sets XLA_FLAGS *before* any jax import.

Mesh geometry (TPU v5e pods): one pod = 256 chips as (data=16, model=16);
multi-pod = 2 pods → (pod=2, data=16, model=16) with the `pod` axis mapped
across DCN. Axis roles: `data` = batch/FSDP/vertex shards, `model` = tensor/
expert/landmark parallel, `pod` = extra data parallelism across pods.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Host-device mesh for CPU runs: (data = n_devices // model, model).

    With the default `model=1` every local device lands on the `data` axis
    (the historical degenerate shape). Pass `model>1` to split off a
    landmark-parallel axis — e.g. under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``, `model=4`
    yields a (data=2, model=4) mesh. `core/shard.py` runs the BatchHL
    stack on this mesh; `launch/serve.py --mesh host --shards M` wires it
    into the serving loop.
    """
    n = len(jax.devices())
    if model < 1 or n % model:
        raise ValueError(
            f"model-axis size {model} must divide the {n} local devices")
    return jax.make_mesh((n // model, model), ("data", "model"))
