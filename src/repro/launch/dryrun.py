import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax import: jax locks the device count on first init.
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax                                   # noqa: E402
from jax.sharding import NamedSharding       # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.configs import common                    # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces:
  * compiled.memory_analysis()  — proves the program fits HBM,
  * compiled.cost_analysis()    — per-device HLO FLOPs / bytes,
  * per-type collective bytes parsed from the post-SPMD HLO text,
and writes one JSON record per cell under experiments/dryrun/.
"""

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the per-device HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result = shape op-name(...)
        for coll in _COLLECTIVES:
            if f" {coll}(" in stripped or f" {coll}-start(" in stripped:
                m = _SHAPE_RE.search(stripped.split("=", 1)[-1])
                if m:
                    out[coll] += _shape_bytes(m.group(1), m.group(2))
                    counts[coll] += 1
                break
    out_total = sum(out.values())
    return {"per_type_bytes": out, "counts": counts, "total_bytes": out_total}


def run_cell(arch: str, shape: str, multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = common.build_cell(arch, shape, pod=multi_pod)

    def to_sharding(spec_tree):
        return jax.tree.map(
            lambda spec: NamedSharding(mesh, spec), spec_tree,
            is_leaf=lambda x: isinstance(x, P))

    t0 = time.time()
    with mesh:
        in_sh = tuple(to_sharding(s) for s in cell.in_specs)
        out_sh = to_sharding(cell.out_specs)
        jitted = jax.jit(cell.step_fn, in_shardings=in_sh,
                         out_shardings=out_sh)
        lowered = jitted.lower(*cell.arg_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        coll = parse_collective_bytes(compiled.as_text())

    record = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": 512 if multi_pod else 256,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "cost": {k: cost.get(k) for k in
                 ("flops", "bytes accessed", "transcendentals")},
        "collectives": coll,
        "flops_note": cell.flops_note,
    }
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch id; default = all assigned archs")
    ap.add_argument("--shape", default=None,
                    help="shape name; default = all shapes of the arch")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--include-batchhl", action="store_true",
                    help="also dry-run the paper's own BatchHL service")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(common.ALL_ARCHS)
    if args.include_batchhl and "batchhl" not in archs:
        archs.append("batchhl")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_fail = 0
    for arch in archs:
        shapes = [args.shape] if args.shape else \
            list(common.arch_shapes(arch))
        for shape in shapes:
            for multi_pod in meshes:
                tag = f"{arch}__{shape}__{'multi' if multi_pod else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    rec = run_cell(arch, shape, multi_pod)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    n_ok += 1
                    print(f"OK   {tag}: compile={rec['compile_s']}s "
                          f"flops={rec['cost'].get('flops')} "
                          f"coll={rec['collectives']['total_bytes']}")
                except Exception as e:  # noqa: BLE001
                    n_fail += 1
                    print(f"FAIL {tag}: {e}")
                    traceback.print_exc()
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
