"""schnet [gnn]: n_interactions=3 d_hidden=64 rbf=300 cutoff=10
[arXiv:1706.08566; paper]."""
from repro.models.gnn import GNNConfig

ARCH_ID = "schnet"
FAMILY = "gnn"
SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")


def model_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID, arch="schnet", d_in=16, d_hidden=64,
                     d_out=1, n_interactions=3, n_rbf=300, cutoff=10.0)


def reduced_config() -> GNNConfig:
    return GNNConfig(name=ARCH_ID + "-smoke", arch="schnet", d_in=8,
                     d_hidden=16, d_out=1, n_interactions=2, n_rbf=12,
                     cutoff=10.0)
