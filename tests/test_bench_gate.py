"""Pins for the bench regression gate (`benchmarks/compare.py`).

Each test drives the real CLI in a subprocess — exactly how the CI bench
job invokes it — against tiny synthetic ``repro-bench/v1`` payloads.
Three behaviours are load-bearing for CI and pinned here:

- a baseline ``serve/.../max_qps_*`` row absent from the candidate run
  is a gate failure (coverage loss counts as a regression), not a
  silent pass;
- a zero-throughput max_qps row fails (inverted ratio goes to inf);
- a non-finite measurement (NaN from a broken emitter) fails instead of
  sailing through every ``>`` comparison as False.
"""
import json
import subprocess
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

QPS_DERIVED = "better=higher; saturation throughput (replica tier)"


def _payload(rows):
    return {"schema": "repro-bench/v1",
            "rows": [dict({"name": name, "us_per_call": us}, **extra)
                     for name, us, extra in rows]}


def _run_gate(tmp_path, base_rows, cand_rows, *extra_args):
    base_path = tmp_path / "base.json"
    cand_path = tmp_path / "cand.json"
    base_path.write_text(json.dumps(_payload(base_rows)))
    cand_path.write_text(json.dumps(_payload(cand_rows)))
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.compare",
         str(base_path), str(cand_path), "--max-regression", "0.25"],
        cwd=REPO_ROOT, capture_output=True, text=True)


TICK = ("ticks/ba_2k/pallas/none/update", 30000.0, {})
QPS = ("serve/ba_2k/jnp/max_qps_r2", 338.0, {"derived": QPS_DERIVED})


def test_identical_rows_pass(tmp_path):
    res = _run_gate(tmp_path, [TICK, QPS], [TICK, QPS])
    assert res.returncode == 0, res.stderr
    assert "OK: no gated row" in res.stdout


def test_missing_max_qps_row_fails(tmp_path):
    # The replica tier's saturation rows are emitted by a separate code
    # path from the tick rows; if that path silently stops running, the
    # gate must treat the vanished row as a regression.
    res = _run_gate(tmp_path, [TICK, QPS], [TICK])
    assert res.returncode == 1
    assert "missing from candidate" in res.stderr
    assert "max_qps_r2" in res.stderr


def test_zero_qps_fails(tmp_path):
    res = _run_gate(tmp_path, [QPS], [(QPS[0], 0.0, QPS[2])])
    assert res.returncode == 1
    assert "max_qps_r2" in res.stderr


def test_qps_drop_gates_inverted_ratio(tmp_path):
    # better=higher rows invert the ratio: a 50% qps drop must fail
    # even though the raw cand/base ratio is < 1.
    res = _run_gate(tmp_path, [QPS], [(QPS[0], 169.0, QPS[2])])
    assert res.returncode == 1
    res = _run_gate(tmp_path, [QPS], [(QPS[0], 400.0, QPS[2])])
    assert res.returncode == 0, res.stderr


def test_nan_candidate_fails(tmp_path):
    res = _run_gate(tmp_path, [TICK], [(TICK[0], float("nan"), {})])
    assert res.returncode == 1
    assert "non-finite" in res.stderr


def test_nan_baseline_fails(tmp_path):
    res = _run_gate(tmp_path, [(TICK[0], float("nan"), {})], [TICK])
    assert res.returncode == 1
    assert "non-finite" in res.stderr


def test_nan_fails_even_below_min_us_floor(tmp_path):
    # NaN also defeats the `b >= min_us` floor check (False), which used
    # to park the row in the ungated bucket; a broken emitter must fail
    # regardless of the floor.
    small = ("ticks/ba_2k/jnp/none/query", float("nan"), {})
    res = _run_gate(tmp_path, [small], [small])
    assert res.returncode == 1
    assert "non-finite" in res.stderr


def test_nan_calibration_row_rejected(tmp_path):
    base_path = tmp_path / "base.json"
    cand_path = tmp_path / "cand.json"
    base_path.write_text(json.dumps(_payload([TICK, QPS])))
    cand_path.write_text(json.dumps(_payload(
        [(TICK[0], float("nan"), {}), QPS])))
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.compare",
         str(base_path), str(cand_path), "--calibrate", TICK[0]],
        cwd=REPO_ROOT, capture_output=True, text=True)
    assert res.returncode != 0
    assert "non-finite or zero" in res.stderr
