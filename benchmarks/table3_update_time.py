"""Paper Table 3: batch update time — BHL⁺ vs BHL vs BHLˢ vs UHL⁺ across
fully-dynamic / incremental / decremental settings.

The headline claim reproduced here: batch-dynamic variants beat the
single-update loop (UHL⁺) by a wide margin because one vertex affected by
many updates is searched/repaired once, not once per update.
"""
from __future__ import annotations

import jax

from repro.graphs.coo import make_batch
from repro.core.batch import (batchhl_update, batchhl_update_split,
                              uhl_update)
from benchmarks import common as cm

BATCH = 128
DATASETS = ("ba_2k", "ba_10k", "er_5k")
MODES = ("mixed", "incremental", "decremental")


def run(datasets=DATASETS, batch=BATCH, unit_updates: int = 16) -> list[str]:
    rows = []
    for ds in datasets:
        inst = cm.build_instance(ds)
        for mode in MODES:
            ups = cm.update_stream(inst.edges, inst.n, batch, mode, seed=7)
            b = make_batch(ups, pad_to=batch)

            t_bhlp = cm.timeit(
                lambda: batchhl_update(inst.g, b, inst.lab, improved=True))
            rows.append(cm.emit(f"table3/{ds}/{mode}/BHL+", t_bhlp,
                                f"batch={batch}"))
            t_bhl = cm.timeit(
                lambda: batchhl_update(inst.g, b, inst.lab, improved=False))
            rows.append(cm.emit(f"table3/{ds}/{mode}/BHL", t_bhl,
                                f"batch={batch}"))
            t_s = cm.timeit(
                lambda: batchhl_update_split(inst.g, b, inst.lab))
            rows.append(cm.emit(f"table3/{ds}/{mode}/BHLs", t_s,
                                f"batch={batch}"))
            # UHL+ on a prefix of the batch, scaled to the full batch size
            small = make_batch(ups[:unit_updates], pad_to=unit_updates)
            t_u = cm.timeit(
                lambda: uhl_update(inst.g, small, inst.lab), iters=1)
            t_u_scaled = t_u * batch / unit_updates
            rows.append(cm.emit(f"table3/{ds}/{mode}/UHL+", t_u_scaled,
                                f"scaled_from={unit_updates}"))
    return rows


if __name__ == "__main__":
    run()
