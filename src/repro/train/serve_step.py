"""Serving-step factories: prefill, decode, and a sampling generate loop.

The dry-run cells lower these same paths at pod scale; this module is the
host-facing API (used by examples and tests): build a cache, prefill the
prompt, then step the decoder with temperature sampling.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm


def make_cache(cfg, batch: int, max_len: int):
    shapes = tfm.cache_shapes(cfg, batch, max_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def make_prefill_step(cfg) -> Callable:
    """(params, cache, tokens[B,S]) → (last-token logits [B,V], cache)."""
    def prefill(params, cache, tokens):
        return tfm.decode_step(params, cache, tokens, jnp.int32(0), cfg)
    return jax.jit(prefill)


def make_decode_step(cfg) -> Callable:
    """(params, cache, token[B,1], cache_len) → (logits [B,V], cache)."""
    def decode(params, cache, token, cache_len):
        return tfm.decode_step(params, cache, token, cache_len, cfg)
    return jax.jit(decode)


def generate(params, cfg, prompt: jax.Array, n_new: int,
             temperature: float = 1.0, seed: int = 0,
             max_len: int | None = None) -> jax.Array:
    """Batched autoregressive sampling. prompt [B, S] → [B, S + n_new]."""
    b, s = prompt.shape
    max_len = max_len or (s + n_new + 8)
    # cache length must align with the attention kv-chunking
    max_len = -(-max_len // cfg.kv_chunk) * cfg.kv_chunk
    cache = make_cache(cfg, b, max_len)
    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg)

    logits, cache = prefill(params, cache, prompt)
    key = jax.random.PRNGKey(seed)
    out = [prompt]
    tok = None
    for i in range(n_new):
        key, sub = jax.random.split(key)
        if temperature <= 0:
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        else:
            tok = jax.random.categorical(
                sub, logits.astype(jnp.float32) / temperature
            )[:, None].astype(jnp.int32)
        out.append(tok)
        if i < n_new - 1:
            logits, cache = decode(params, cache, tok, jnp.int32(s + i))
    return jnp.concatenate(out, axis=1)
