"""The replica serve tier, bottom-up: the router's QueryQueue policies
(admission control + microbatch coalescing) in isolation, the wire
protocol, the publish/ack barrier records, the ServeSpec config re-cut's
lossless round-trips — and the crash-recovery integration test: a reader
killed mid-stream, restarted from ``CURRENT``, with every answer checked
against the Dijkstra oracle *at the version it was served* and the
staleness ≤ 1 contract held across the process boundary (DESIGN.md §9).
"""
from __future__ import annotations

import json
import os
import socket
import subprocess
import threading
import time
import warnings

import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.launch import replica
from repro.launch.config import (EngineSpec, GraphSpec, ServeSpec,
                                 StreamSpec, TopologySpec, build_parser,
                                 spec_from_cli)
from repro.launch.replica import QueryQueue


# ---------------------------------------------------------------------------
# QueryQueue: admission control
# ---------------------------------------------------------------------------

def test_admission_counts_queries_not_requests():
    q = QueryQueue(max_pending=10, microbatch=32, coalesce_s=0.0)
    assert q.offer("a", 6)
    assert q.offer("b", 4)          # exactly at the cap
    assert q.pending == 10
    assert not q.offer("c", 1)      # one over: refused
    assert q.rejected == 1
    assert q.pending == 10          # refusal left the queue untouched


def test_admission_exempts_front_requeue():
    """A batch reclaimed from a dead reader re-enters at the head even
    when the queue is full — a reader crash must not surface as client
    rejections."""
    q = QueryQueue(max_pending=4, microbatch=32, coalesce_s=0.0)
    assert q.offer("a", 4)
    assert not q.offer("b", 1)
    assert q.offer("requeued", 3, front=True)
    assert q.pending == 7
    assert q.take() == ["requeued", "a"]  # head position preserved


def test_front_requeue_never_counts_as_rejected():
    """The rejected counter is admission refusals only: an exempt
    front-requeue past the cap must neither bump it nor unbalance the
    pending count across the eventual take."""
    q = QueryQueue(max_pending=4, microbatch=32, coalesce_s=0.0)
    assert q.offer("a", 4)
    assert not q.offer("b", 2)
    assert q.rejected == 2
    assert q.offer("r", 3, front=True)   # reclaimed batch
    assert q.rejected == 2               # exempt → uncounted
    assert q.pending == 7
    assert q.take() == ["r", "a"]
    assert q.pending == 0                # requeued queries fully drained


def test_coalesce_split_refusal_leaves_counters_intact():
    """When the next entry doesn't fit the open microbatch the coalescer
    refuses to split it and leaves it queued whole — that refusal is not
    an admission reject and must not leak pending queries."""
    q = QueryQueue(max_pending=100, microbatch=8, coalesce_s=0.01)
    q.offer("a", 6)
    q.offer("b", 5)                  # 6+5 > 8: left whole for next take
    assert q.take() == ["a"]
    assert q.pending == 5            # the refused entry is still accounted
    assert q.rejected == 0
    assert q.take() == ["b"]
    assert q.pending == 0


# ---------------------------------------------------------------------------
# Router counters (the stats doc the benchmarks and operators read)
# ---------------------------------------------------------------------------

def _router(tmp_path, microbatch=4, max_queue=2, readers=()):
    spec = ServeSpec(
        stream=StreamSpec(microbatch=microbatch, quiet=True),
        topology=TopologySpec(max_queue=max_queue))
    return replica.Router(spec, str(tmp_path), port=0,
                          reader_addrs=list(readers))


def test_router_counts_oversized_and_rejected_once(tmp_path):
    """Regression, two counter bugs in one client session: (a) the
    oversized-request REJECT path reported nothing at all, and (b) an
    admission refusal was counted twice — once by `QueryQueue.offer`,
    once again by the client loop. The stats doc must show each refusal
    exactly once, under its actual cause."""
    router = _router(tmp_path, microbatch=4, max_queue=2)
    client, server = socket.socketpair()
    t = threading.Thread(target=router._client_loop, args=(server,),
                         daemon=True)
    t.start()
    try:
        big = np.arange(6, dtype=np.int32)       # > microbatch
        replica.send_msg(client, replica.MSG_QUERY,
                         replica.pack_query(big, big))
        kind, _ = replica.recv_msg(client)
        assert kind == replica.MSG_REJECT
        two = np.arange(2, dtype=np.int32)       # fills max_queue exactly
        replica.send_msg(client, replica.MSG_QUERY,
                         replica.pack_query(two, two))
        one = np.arange(1, dtype=np.int32)       # one over: refused
        replica.send_msg(client, replica.MSG_QUERY,
                         replica.pack_query(one, one))
        kind, _ = replica.recv_msg(client)
        assert kind == replica.MSG_REJECT
        replica.send_msg(client, replica.MSG_STATS)
        kind, payload = replica.recv_msg(client)
        assert kind == replica.MSG_STATS
        stats = json.loads(payload)
        assert stats["oversized"] == 6           # queries, its own cause
        assert stats["rejected"] == 1            # once, owned by the queue
        assert router.queue.rejected == 1
        assert stats["pending"] == 2             # the admitted entry
    finally:
        replica.send_msg(client, replica.MSG_STOP)
        t.join(timeout=5.0)
        client.close()


def test_router_requeued_counts_queries_not_entries(tmp_path):
    """Regression: the dead-reader requeue path bumped `requeued` by
    len(batch) — entries — while every other stat is query-denominated.
    One reclaimed 3-query batch must count as 3."""
    srv = socket.create_server(("127.0.0.1", 0))
    addr = srv.getsockname()

    def accept_and_drop():
        conn, _ = srv.accept()
        replica.recv_msg(conn)       # take the dispatched batch...
        conn.close()                 # ...and die before answering
        srv.close()                  # no reconnect: one failure exactly

    threading.Thread(target=accept_and_drop, daemon=True).start()
    router = _router(tmp_path, microbatch=8, max_queue=16, readers=[addr])
    qs = np.arange(3, dtype=np.int32)
    entry = replica._Entry(None, threading.Lock(), qs, qs)
    assert router.queue.offer(entry, qs.size)
    t = threading.Thread(target=router._dispatch_loop, args=(0,),
                         daemon=True)
    t.start()
    try:
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            with router._stats_lock:
                if router.stats["requeued"]:
                    break
            time.sleep(0.01)
        assert router.stats["requeued"] == 3     # queries, not 1 entry
        assert router.stats["reader_errors"][0] == 1
        assert router.queue.pending == 3         # reclaimed at the head
    finally:
        router.running = False
        t.join(timeout=5.0)


# ---------------------------------------------------------------------------
# QueryQueue: coalescing
# ---------------------------------------------------------------------------

def test_coalesce_merges_up_to_microbatch():
    q = QueryQueue(max_pending=100, microbatch=8, coalesce_s=10.0)
    for name, m in (("a", 3), ("b", 3), ("c", 2), ("d", 1)):
        q.offer(name, m)
    # 3+3+2 fills the microbatch exactly; "d" stays for the next take —
    # and a full batch returns without waiting out the 10s window.
    t0 = time.monotonic()
    assert q.take() == ["a", "b", "c"]
    assert time.monotonic() - t0 < 5.0
    assert q.pending == 1


def test_coalesce_never_splits_entries():
    """Entries are whole client requests — each must be answered at one
    version, so the coalescer takes them entirely or not at all."""
    q = QueryQueue(max_pending=100, microbatch=8, coalesce_s=0.01)
    q.offer("a", 5)
    q.offer("b", 5)                  # 5+5 > 8: must not be split
    assert q.take() == ["a"]
    assert q.take() == ["b"]


def test_coalesce_dispatches_oversized_alone():
    q = QueryQueue(max_pending=100, microbatch=8, coalesce_s=0.01)
    q.offer("big", 20)               # admitted (<=max_pending), > microbatch
    q.offer("small", 1)
    assert q.take() == ["big"]       # oversized runs alone
    assert q.take() == ["small"]


def test_coalesce_window_closes_on_partial_batch():
    q = QueryQueue(max_pending=100, microbatch=32, coalesce_s=0.05)
    q.offer("a", 2)
    t0 = time.monotonic()
    assert q.take(timeout=5.0) == ["a"]
    assert time.monotonic() - t0 < 2.0   # window (50ms), not timeout (5s)


def test_take_empty_after_timeout():
    q = QueryQueue(max_pending=10, microbatch=8, coalesce_s=0.01)
    assert q.take(timeout=0.01) == []


def test_take_picks_up_late_arrivals_inside_window():
    q = QueryQueue(max_pending=100, microbatch=8, coalesce_s=0.5)
    got = []
    t = threading.Thread(target=lambda: got.extend(q.take(timeout=2.0)))
    q.offer("a", 2)
    t.start()
    time.sleep(0.05)
    q.offer("b", 2)                  # lands inside the open window
    t.join()
    assert got == ["a", "b"]


# ---------------------------------------------------------------------------
# Wire protocol
# ---------------------------------------------------------------------------

def test_query_answer_pack_roundtrip():
    qs = np.arange(5, dtype=np.int32)
    qt = np.arange(5, 10, dtype=np.int32)
    qs2, qt2 = replica.unpack_query(replica.pack_query(qs, qt))
    np.testing.assert_array_equal(qs, qs2)
    np.testing.assert_array_equal(qt, qt2)
    v, h, d = replica.unpack_answer(
        replica.pack_answer(7, 8, np.asarray([1, 2, 3], np.int32)))
    assert (v, h) == (7, 8)
    np.testing.assert_array_equal(d, [1, 2, 3])


# ---------------------------------------------------------------------------
# Publish/ack records (the barrier's inputs)
# ---------------------------------------------------------------------------

def test_publish_requires_saved_step(tmp_path):
    d = str(tmp_path)
    with pytest.raises(FileNotFoundError):
        ckpt.publish(d, 3)
    ckpt.save(d, 3, {"x": np.arange(4)})
    rec = ckpt.publish(d, 3)
    assert rec["version"] == 3
    assert ckpt.current_step(d) == 3


def test_prune_never_removes_published_step(tmp_path):
    d = str(tmp_path)
    for s in range(5):
        ckpt.save(d, s, {"x": np.arange(4) + s})
    ckpt.publish(d, 1)
    ckpt.prune(d, keep=2)
    assert ckpt.current_step(d) == 1
    assert ckpt.step_manifest(d, 1) is not None      # published: protected
    assert ckpt.step_manifest(d, 4) is not None      # newest: kept
    assert ckpt.step_manifest(d, 0) is None          # pruned


def test_prune_keeps_steps_between_current_and_latest(tmp_path):
    """Regression: prune protected only the step CURRENT names, so with
    an old pointer and an aggressive keep it deleted the steps between
    CURRENT and the head — breaking a reader that loaded CURRENT and is
    replaying forward to catch up. The whole [CURRENT, latest] range
    must survive."""
    d = str(tmp_path)
    for s in range(6):
        ckpt.save(d, s, {"x": np.arange(4) + s})
    ckpt.publish(d, 2)                               # pointer lags the head
    ckpt.prune(d, keep=1)
    for s in range(2, 6):                            # published..latest
        assert ckpt.step_manifest(d, s) is not None, s
    assert ckpt.step_manifest(d, 0) is None          # strictly older: pruned
    assert ckpt.step_manifest(d, 1) is None
    # No pointer yet: plain newest-k retention still applies.
    d2 = str(tmp_path / "unpublished")
    for s in range(3):
        ckpt.save(d2, s, {"x": np.arange(4) + s})
    ckpt.prune(d2, keep=1)
    assert ckpt.step_manifest(d2, 2) is not None
    assert ckpt.step_manifest(d2, 0) is None


def test_ack_barrier_ignores_dead_readers(tmp_path):
    d = str(tmp_path)
    replica.write_ack(d, 0, version=5)               # live: this process
    # A pid that has definitely exited: a finished child.
    p = subprocess.Popen(["true"])
    p.wait()
    replica.write_ack(d, 1, version=0)
    acks = replica.read_acks(d)
    rec = dict(acks[1])
    rec["pid"] = p.pid
    ckpt.write_json_atomic(
        os.path.join(d, "acks", "reader_1.json"), rec)
    # Reader 1 is behind but dead — the barrier must not wait for it.
    assert replica.wait_for_acks(d, version=5, timeout_s=5.0)


def test_ack_barrier_times_out_on_live_laggard(tmp_path):
    d = str(tmp_path)
    replica.write_ack(d, 0, version=1)               # live (us), behind
    t0 = time.monotonic()
    assert not replica.wait_for_acks(d, version=2, timeout_s=0.1,
                                     log=lambda *a: None)
    assert time.monotonic() - t0 < 2.0


# ---------------------------------------------------------------------------
# ServeSpec round-trips (the config re-cut's losslessness contract)
# ---------------------------------------------------------------------------

def _nondefault_spec() -> ServeSpec:
    return ServeSpec(
        graph=GraphSpec(n=500, deg=3, landmarks=8, capacity=640, grow=True),
        engine=EngineSpec(backend="pallas", block_v=128, fused=True),
        stream=StreamSpec(batches=3, qps=123.5, pipeline=True, verify=True),
        topology=TopologySpec(readers=3, coalesce_ms=5.0, restart=True))


def test_spec_cli_roundtrip():
    spec = _nondefault_spec()
    ap = build_parser("t")
    ns = ap.parse_args(spec.to_args())
    assert ServeSpec.from_parsed_args(ns) == spec


def test_spec_json_roundtrip(tmp_path):
    spec = _nondefault_spec()
    path = str(tmp_path / "spec.json")
    spec.save_json(path)
    assert ServeSpec.load_json(path) == spec


def test_spec_serve_config_roundtrip():
    spec = _nondefault_spec()
    cfg = spec.to_serve_config()
    assert cfg.n == 500 and cfg.backend == "pallas" and cfg.qps == 123.5
    back = ServeSpec.from_serve_config(cfg, topology=spec.topology)
    assert back == spec


def test_flat_flags_alone_are_the_spec():
    ap = build_parser("t")
    ns = ap.parse_args(["--n", "700"])
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        spec = spec_from_cli(ns, ap)
    assert spec.graph.n == 700
    assert not w                     # flat-only: supported, no warning


def test_flat_overrides_alongside_config_warn_deprecated(tmp_path):
    path = str(tmp_path / "spec.json")
    _nondefault_spec().save_json(path)
    ap = build_parser("t")
    ns = ap.parse_args(["--config", path, "--n", "700"])
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        spec = spec_from_cli(ns, ap)
    assert spec.graph.n == 700               # flat flag overrode the JSON
    assert spec.engine.backend == "pallas"   # the rest came from the JSON
    assert any(issubclass(x.category, DeprecationWarning) for x in w)


def test_realized_n_road_rounds_to_grid():
    import math
    gs = GraphSpec(n=2025, graph="road")
    rows = max(2, math.isqrt(2025))
    assert gs.realized_n() == rows * max(2, (2025 + rows - 1) // rows)
    assert GraphSpec(n=2025).realized_n() == 2025


# ---------------------------------------------------------------------------
# Crash recovery: kill a reader mid-stream, restart from CURRENT,
# zero wrong answers at each answer's served version, staleness <= 1.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_reader_crash_recovery(tmp_path):
    spec = ServeSpec(
        graph=GraphSpec(n=300, deg=3, landmarks=8),
        stream=StreamSpec(batches=3, batch_size=30, queries=0,
                          microbatch=16, seed=3, quiet=True),
        topology=TopologySpec(readers=2, restart=True))
    topo = replica.ReplicaTopology(spec, str(tmp_path))
    killed = [False]

    def kill_once():
        # Mid-stream, not at the edges: the victim is likely holding an
        # in-flight batch, which must be requeued and answered elsewhere.
        if not killed[0] and time.monotonic() > t_kill[0]:
            killed[0] = True
            topo.kill_reader(0)

    try:
        topo.start()
        t_kill = [time.monotonic() + 1.0]
        report = replica.stream_queries(spec, topo, total=240, qps=120.0,
                                        on_tick=kill_once)
        assert killed[0]
        assert topo.updater_ok()
        assert topo.reader_restarts >= 1
        # No client-visible loss: every query either answered or (at
        # most transiently, while one reader was down) rejected.
        assert len(report.answers) + report.rejected == 240
        assert len(report.answers) >= 200
        assert report.max_staleness() <= 1
        # The heart of the contract: zero wrong answers, each checked
        # against Dijkstra on the graph at the version that served it.
        assert replica.verify_answers(str(tmp_path), report.answers) == 0
    finally:
        topo.stop()
