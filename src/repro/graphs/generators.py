"""Synthetic graph generators (host-side numpy) for tests and benchmarks.

Complex networks in the paper are small-diameter power-law graphs; the
Barabási–Albert generator reproduces that regime. Grid meshes feed
GraphCast-style configs; molecule batches feed SchNet/DimeNet/MACE.
"""
from __future__ import annotations

import math

import numpy as np


def barabasi_albert(n: int, m: int, seed: int = 0) -> np.ndarray:
    """BA preferential attachment; returns unique undirected edges [E, 2]."""
    rng = np.random.default_rng(seed)
    targets = list(range(m))
    repeated: list[int] = []
    edges = []
    for v in range(m, n):
        for t in set(targets):
            edges.append((v, t))
        repeated.extend(targets)
        repeated.extend([v] * m)
        targets = [int(repeated[rng.integers(len(repeated))])
                   for _ in range(m)]
    return _dedupe(np.asarray(edges, np.int32))


def erdos_renyi(n: int, p: float, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    rows, cols = np.triu_indices(n, k=1)
    keep = rng.random(rows.shape[0]) < p
    return np.stack([rows[keep], cols[keep]], axis=1).astype(np.int32)


def random_connected(n: int, extra_edges: int, seed: int = 0) -> np.ndarray:
    """Random tree + extra random edges — always connected."""
    rng = np.random.default_rng(seed)
    edges = [(v, int(rng.integers(v))) for v in range(1, n)]
    for _ in range(extra_edges):
        u, v = rng.integers(n), rng.integers(n)
        if u != v:
            edges.append((int(u), int(v)))
    return _dedupe(np.asarray(edges, np.int32))


def grid_mesh(rows: int, cols: int) -> np.ndarray:
    """4-connected grid (GraphCast-style regular mesh)."""
    idx = np.arange(rows * cols).reshape(rows, cols)
    e = []
    e.append(np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1))
    e.append(np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1))
    return np.concatenate(e).astype(np.int32)


def road_grid(n: int, max_weight: int = 8, seed: int = 0) -> np.ndarray:
    """Road-like weighted planar graph: a 4-connected grid of ~n vertices
    with uniform integer weights in [1, max_weight] per edge — the
    road-network regime (large diameter, bounded degree) the weighted
    metric targets, as opposed to the small-diameter power-law regime of
    `barabasi_albert`. Returns edges [E, 3] = (u, v, w); the vertex count
    is rows·cols = `edges[:, :2].max() + 1` (the grid is connected)."""
    rows = max(2, int(math.isqrt(n)))
    cols = max(2, (n + rows - 1) // rows)
    e = grid_mesh(rows, cols)
    rng = np.random.default_rng(seed)
    w = rng.integers(1, max_weight + 1, size=e.shape[0])
    return np.concatenate([e, w[:, None]], axis=1).astype(np.int32)


def molecule_batch(n_mols: int, atoms_per_mol: int, seed: int = 0
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Batched random molecules: positions [N,3] + radius-graph edges."""
    rng = np.random.default_rng(seed)
    pos = rng.normal(size=(n_mols * atoms_per_mol, 3)).astype(np.float32)
    edges = []
    for m in range(n_mols):
        base = m * atoms_per_mol
        p = pos[base:base + atoms_per_mol]
        d = np.linalg.norm(p[:, None] - p[None, :], axis=-1)
        src, dst = np.nonzero((d < 1.8) & (d > 0))
        keep = src < dst
        edges.append(np.stack([src[keep] + base, dst[keep] + base], axis=1))
    return pos, np.concatenate(edges).astype(np.int32)


def random_batch_updates(edges: np.ndarray, n: int, n_ins: int, n_del: int,
                         seed: int = 0, existing=None, n_rew: int = 0,
                         max_weight: int = 1) -> list[tuple]:
    """Valid updates: deletions sampled from existing edges, insertions are
    fresh non-edges (paper §3: invalid updates are ignored), reweights
    (`n_rew` > 0) re-draw the weight of existing edges not already chosen
    for deletion. With `max_weight` > 1 inserts/reweights carry a uniform
    weight in [1, max_weight] as 4-tuples (u, v, op, w); the default
    (n_rew=0, max_weight=1) emits the legacy (u, v, is_del) 3-tuples from
    a bit-identical rng sequence.

    `existing` optionally passes a prebuilt membership set/dict of
    canonical (min, max) edge keys, sparing the O(E) rebuild per call for
    callers that maintain one incrementally (launch/serve.py).
    """
    rng = np.random.default_rng(seed)
    pairs = edges[:, :2] if getattr(edges, "ndim", 0) == 2 \
        and edges.shape[0] and edges.shape[1] > 2 else edges
    if existing is None:
        existing = {(min(u, v), max(u, v)) for u, v in pairs}
    out: list[tuple] = []
    if n_del:
        sel = rng.choice(len(edges), size=min(n_del, len(edges)),
                         replace=False)
        chosen = set()
        for i in sel:
            u, v = int(edges[i, 0]), int(edges[i, 1])
            out.append((u, v, True))
            chosen.add((min(u, v), max(u, v)))
    else:
        chosen = set()
    tries = 0
    while sum(1 for e in out if not e[2]) < n_ins and tries < 100 * n_ins + 100:
        tries += 1
        u, v = int(rng.integers(n)), int(rng.integers(n))
        key = (min(u, v), max(u, v))
        if u == v or key in existing or key in chosen:
            continue
        chosen.add(key)
        if max_weight > 1:
            out.append((u, v, 0, int(rng.integers(1, max_weight + 1))))
        else:
            out.append((u, v, False))
    if n_rew and len(edges):
        sel = rng.choice(len(edges), size=min(n_rew, len(edges)),
                         replace=False)
        for i in sel:
            u, v = int(edges[i, 0]), int(edges[i, 1])
            key = (min(u, v), max(u, v))
            if key in chosen:
                continue
            chosen.add(key)
            out.append((u, v, 2, int(rng.integers(1, max(2, max_weight + 1)))))
    rng.shuffle(out)
    return out


def zipf_vertices(rng: np.random.Generator, n: int, size: int,
                  a: float = 1.2) -> np.ndarray:
    """Bounded-Zipf(a) vertex ids over [0, n): P(id = k) ∝ (k + 1)^-a.

    Rank maps to id directly: low ids are the oldest (highest-degree)
    vertices in the BA generator above, so skewed query traffic
    concentrates on the network's hubs — the hot-source serving scenario
    (`data/scenarios.py`). The law is normalized over [0, n) rather than
    sampled unbounded and clipped: clipping would pile the entire tail
    mass (~20% at a=1.2, n=2000) onto vertex n-1, the *newest*
    lowest-degree vertex — the opposite of a hub.
    """
    if a <= 1.0:
        raise ValueError(f"zipf exponent must be > 1, got {a}")
    w = np.arange(1, n + 1, dtype=np.float64) ** -a
    return rng.choice(n, size=size, p=w / w.sum()).astype(np.int32)


def _dedupe(edges: np.ndarray) -> np.ndarray:
    if edges.size == 0:
        return edges.reshape(0, 2)
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    keep = lo != hi
    uniq = np.unique(np.stack([lo[keep], hi[keep]], axis=1), axis=0)
    return uniq.astype(np.int32)
