"""Versioned snapshots and the chunked-update serving pipeline.

The serving architecture of DESIGN.md §5: queries must stay fast *while*
the graph churns (the paper's premise), but a monolithic
`batchhl_update` is one device dispatch — on a single execution queue,
any query enqueued behind it waits for the whole update, so tail latency
is bounded below by update time. This module breaks that head-of-line
blocking with two pieces:

* **`Snapshot` / `SnapshotStore`** — an immutable serving unit
  (graph + labelling + prepared `RelaxPlan` + version id) behind a
  single-writer many-reader store. Queries always dispatch against the
  *committed* snapshot; an update builds snapshot N+1 off to the side
  and `commit` swaps the pointer atomically. JAX arrays are immutable,
  so in-flight queries against snapshot N stay valid across the swap —
  answers are always exact *at some committed version* (bounded
  staleness, never inconsistency).

* **`pipelined_update`** — the BatchHL update (batch search Algos 2–3 +
  batch repair Algo 4) as a generator of *bounded* device dispatches:
  seed, then fixpoint sweeps in chunks of `chunk_sweeps` waves, then
  repair likewise, then finalize. The caller interleaves query
  microbatches at every yield; because each chunk is a fixed number of
  relaxation sweeps, a query enqueued behind it waits at most one chunk
  (a few sweeps) instead of the full update. The chunk bodies are the
  *same* seed/step functions the monolithic fixpoints use
  (`core/batch.py`), and the fixpoint is monotone, so the committed
  labelling is bit-identical to `batchhl_update` — extra converged
  sweeps are no-ops (`tests/test_pipeline.py` pins it).

Under a mesh the chunks run through the `core/shard.py` wrappers with
the maintenance plane grouping (landmark planes over data×model) while
query microbatches keep the query grouping (planes over model, batch
over data) — the regrouping contract of DESIGN.md §4, now interleaved
on the same device queue instead of serialized.

Checkpointing: `save_snapshot` / `restore_snapshot` persist the *full*
serve state — graph topology (src/dst/valid), labelling, and version —
so a restarted loop resumes exactly (the `RelaxPlan` is derived state,
re-prepared by the engine on restore).
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.coo import (Graph, BatchUpdate, INF_D, apply_batch, grow,
                              resolve_seed_weights)
from repro.checkpoint import manager as ckpt
from repro.core.batch import (check_labelling_width, frontier_wave,
                              repair_base, repair_base_frontier,
                              repair_merge, repair_step, repair_step_rows,
                              search_basic_seed, search_basic_step,
                              search_improved_seed, search_improved_step,
                              search_step_rows, use_frontier)
from repro.core.engine import RelaxPlan
from repro.core.labelling import (HighwayLabelling, INF_KEY2, INF_KEY4,
                                  grow_labelling,
                                  key2_dist, key2_hub, key2_make,
                                  per_plane_hub_mask)


class UnweightedCheckpointError(FileNotFoundError):
    """A checkpoint from before the weighted-metric format (no graph_w).

    Named so callers can distinguish "old format" from "no checkpoint" /
    "corrupt shapes" — the weight column cannot be defaulted silently
    (w ≡ 1 would be a *guess* about the stream that produced the state).
    """


# ---------------------------------------------------------------------------
# Snapshot + store
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Snapshot:
    """One immutable serving unit: everything a query needs, versioned.

    `plan` is the `RelaxPlan` prepared for this graph snapshot (None on
    the jnp backend); it rides along so queries at version N keep using
    N's tiling even while the engine prepares N+1's.
    """
    version: int
    graph: Graph
    labelling: HighwayLabelling
    plan: RelaxPlan | None = None


class SnapshotStore:
    """Single-writer / many-reader versioned snapshot pointer.

    Reads (`committed`) are one attribute load — atomic under the GIL, no
    lock on the query path. `commit` swaps the pointer and enforces
    contiguous versions, so "answered at version v" is always meaningful.
    """

    def __init__(self, snapshot: Snapshot):
        self._committed = snapshot

    @property
    def committed(self) -> Snapshot:
        return self._committed

    @property
    def version(self) -> int:
        return self._committed.version

    def commit(self, snapshot: Snapshot) -> Snapshot:
        if snapshot.version != self._committed.version + 1:
            raise ValueError(
                f"commit of version {snapshot.version} onto "
                f"{self._committed.version}: versions must be contiguous")
        self._committed = snapshot
        return snapshot


def grow_snapshot(snap: Snapshot, *, capacity: int | None = None,
                  n: int | None = None) -> Snapshot:
    """The grown twin of `snap`: same version, same logical graph, larger
    static slots (DESIGN.md §6).

    Growth is a pure shape change — every edge, distance, and hub flag is
    preserved, and new vertex columns are seeded exactly as a fresh
    construction at the larger size would leave an isolated vertex — so
    the grown snapshot keeps the *same* version: committing happens only
    when the next batch update lands (version + 1, at the grown shapes,
    through the store's pointer swap). Queries keep serving the committed
    pre-growth snapshot meanwhile, preserving the staleness ≤ 1 contract.
    `plan` is dropped: tilings are shape-keyed derived state, and the
    engine's fingerprint (which includes n and capacity) guarantees the
    re-prepare is a clean retile, never a stale-tile reuse.
    """
    g = grow(snap.graph, capacity=capacity, n=n)
    return Snapshot(snap.version, g, grow_labelling(snap.labelling, g.n),
                    None)


# ---------------------------------------------------------------------------
# Bounded update chunks (unsharded; core/shard.py holds the mesh twins)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("improved",))
def search_seed(g_new: Graph, batch: BatchUpdate, dist: jax.Array,
                hub: jax.Array, landmarks: jax.Array, improved: bool = True
                ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Batch-search initial state: (seed keys, seeded, bound, hub_mask).

    `bound` is the per-vertex accept bound of the search step (β for the
    improved Algo 3, d_G for the basic Algo 2); `hub_mask` is reused by
    every later phase of the tick.
    """
    check_labelling_width(g_new, dist)
    hub_mask = per_plane_hub_mask(landmarks, landmarks, g_new.n)
    if improved:
        seed, seeded, beta = search_improved_seed(g_new, batch, dist, hub,
                                                  hub_mask)
        return seed, seeded, beta, hub_mask
    seed, seeded = search_basic_seed(g_new, batch, dist)
    return seed, seeded, dist, hub_mask


@partial(jax.jit, static_argnames=("improved", "sweeps"))
def search_chunk(g_new: Graph, best: jax.Array, seed: jax.Array,
                 bound: jax.Array, hub_mask: jax.Array,
                 plan: RelaxPlan | None, improved: bool = True,
                 sweeps: int = 1) -> tuple[jax.Array, jax.Array]:
    """`sweeps` search waves in one bounded dispatch → (best', changed)."""
    cur = best
    for _ in range(sweeps):
        if improved:
            cur = search_improved_step(plan, g_new, cur, seed, bound,
                                       hub_mask)
        else:
            cur = search_basic_step(plan, g_new, cur, seed, bound)
    return cur, jnp.any(cur != best)


@partial(jax.jit, static_argnames=("improved",))
def search_finish(best: jax.Array, seeded: jax.Array,
                  improved: bool = True) -> jax.Array:
    """Settled search keys → aff[P, V] (the CP/LD-affected supersets)."""
    inf = INF_KEY4 if improved else INF_D
    return seeded | (best < inf)


@jax.jit
def repair_start(g_new: Graph, aff: jax.Array, dist: jax.Array,
                 hub: jax.Array, hub_mask: jax.Array,
                 plan: RelaxPlan | None) -> jax.Array:
    """Algo-4 boundary seeding as one bounded dispatch."""
    return repair_base(plan, g_new, aff, key2_make(dist, hub), hub_mask)


@partial(jax.jit, static_argnames=("sweeps",))
def repair_chunk(g_new: Graph, cur: jax.Array, aff: jax.Array,
                 hub_mask: jax.Array, plan: RelaxPlan | None,
                 sweeps: int = 1) -> tuple[jax.Array, jax.Array]:
    """`sweeps` interior repair waves in one bounded dispatch."""
    out = cur
    for _ in range(sweeps):
        out = repair_step(plan, g_new, out, aff, hub_mask)
    return out, jnp.any(out != cur)


# --- frontier chunk variants (change propagation, DESIGN.md §10) -----------
#
# The masked-sweep twins of the chunks above, used by `pipelined_update`
# when the plan carries a `FrontierTiles`. Each threads the per-plane
# changed-block bitmap `front` [P, NBf] through the chunk loop as extra
# carried state; the per-chunk convergence flag becomes "is the frontier
# empty", which is the same fixpoint condition expressed one wave earlier
# (values are bit-identical either way — the parity suite pins it).

def _search_wave_fns(plan, g_new, seed, bound, hub_mask, improved):
    """(full_step, masked_step) pair for one search wave (Algo 2/3)."""
    if improved:
        return (lambda b: search_improved_step(plan, g_new, b, seed, bound,
                                               hub_mask),
                lambda b, rows_g: search_step_rows(rows_g, b, bound,
                                                   hub_mask, improved=True))
    return (lambda b: search_basic_step(plan, g_new, b, seed, bound),
            lambda b, rows_g: search_step_rows(rows_g, b, bound, None,
                                               improved=False))


@jax.jit
def frontier_seed_blocks(plan: RelaxPlan, seeded: jax.Array) -> jax.Array:
    """Initial changed-block bitmap: wave 0 'changed' the seeded vertices."""
    return plan.frontier.changed_blocks(seeded)


@partial(jax.jit, static_argnames=("improved", "sweeps"))
def search_chunk_frontier(g_new: Graph, best: jax.Array, front: jax.Array,
                          seed: jax.Array, bound: jax.Array,
                          hub_mask: jax.Array, plan: RelaxPlan,
                          improved: bool = True, sweeps: int = 1):
    """`search_chunk` with frontier waves → (best', front', changed)."""
    full, masked = _search_wave_fns(plan, g_new, seed, bound, hub_mask,
                                    improved)
    cur = best
    for _ in range(sweeps):
        cur, front = frontier_wave(plan, g_new, full, masked, cur, front)
    return cur, front, jnp.any(front)


@jax.jit
def repair_start_frontier(g_new: Graph, aff: jax.Array, dist: jax.Array,
                          hub: jax.Array, hub_mask: jax.Array,
                          plan: RelaxPlan):
    """`repair_start` masked to the affected blocks → (base, front)."""
    base = repair_base_frontier(plan, g_new, aff, key2_make(dist, hub),
                                hub_mask)
    return base, plan.frontier.changed_blocks(base < INF_KEY2)


@partial(jax.jit, static_argnames=("sweeps",))
def repair_chunk_frontier(g_new: Graph, cur: jax.Array, front: jax.Array,
                          aff: jax.Array, hub_mask: jax.Array,
                          plan: RelaxPlan, sweeps: int = 1):
    """`repair_chunk` with frontier waves → (cur', front', changed)."""
    full = lambda c: repair_step(plan, g_new, c, aff, hub_mask)
    masked = lambda c, rows_g: repair_step_rows(rows_g, c, aff, hub_mask)
    out = cur
    for _ in range(sweeps):
        out, front = frontier_wave(plan, g_new, full, masked, out, front)
    return out, front, jnp.any(front)


@partial(jax.jit, static_argnames=("improved", "sweeps"))
def fused_search_start_frontier(g_new: Graph, batch: BatchUpdate,
                                dist: jax.Array, hub: jax.Array,
                                landmarks: jax.Array, plan: RelaxPlan,
                                improved: bool = True, sweeps: int = 1):
    """`fused_search_start` with frontier waves →
    (best, front, seed, seeded, bound, hub_mask, changed).

    Returned `best` is a fresh buffer distinct from `seed` (each masked
    wave's scatter-min is functional), so the donation contract of the
    fused chunks holds unchanged.
    """
    check_labelling_width(g_new, dist)
    hub_mask = per_plane_hub_mask(landmarks, landmarks, g_new.n)
    if improved:
        seed, seeded, bound = search_improved_seed(g_new, batch, dist, hub,
                                                   hub_mask)
    else:
        seed, seeded = search_basic_seed(g_new, batch, dist)
        bound = dist
    front = plan.frontier.changed_blocks(seeded)
    full, masked = _search_wave_fns(plan, g_new, seed, bound, hub_mask,
                                    improved)
    best = seed
    for _ in range(sweeps):
        best, front = frontier_wave(plan, g_new, full, masked, best, front)
    return best, front, seed, seeded, bound, hub_mask, jnp.any(front)


@partial(jax.jit, static_argnames=("improved", "sweeps"), donate_argnums=(1,))
def fused_search_chunk_frontier(g_new: Graph, best: jax.Array,
                                front: jax.Array, seed: jax.Array,
                                bound: jax.Array, hub_mask: jax.Array,
                                plan: RelaxPlan, improved: bool = True,
                                sweeps: int = 1):
    """`search_chunk_frontier` with the labelling plane donated."""
    full, masked = _search_wave_fns(plan, g_new, seed, bound, hub_mask,
                                    improved)
    cur = best
    for _ in range(sweeps):
        cur, front = frontier_wave(plan, g_new, full, masked, cur, front)
    return cur, front, jnp.any(front)


@partial(jax.jit, static_argnames=("sweeps",))
def fused_repair_start_chunk_frontier(g_new: Graph, aff: jax.Array,
                                      dist: jax.Array, hub: jax.Array,
                                      hub_mask: jax.Array, plan: RelaxPlan,
                                      sweeps: int = 1):
    """`fused_repair_start_chunk` with frontier waves →
    (cur, front, changed)."""
    cur = repair_base_frontier(plan, g_new, aff, key2_make(dist, hub),
                               hub_mask)
    front = plan.frontier.changed_blocks(cur < INF_KEY2)
    full = lambda c: repair_step(plan, g_new, c, aff, hub_mask)
    masked = lambda c, rows_g: repair_step_rows(rows_g, c, aff, hub_mask)
    for _ in range(sweeps):
        cur, front = frontier_wave(plan, g_new, full, masked, cur, front)
    return cur, front, jnp.any(front)


@partial(jax.jit, static_argnames=("sweeps",), donate_argnums=(1,))
def fused_repair_chunk_frontier(g_new: Graph, cur: jax.Array,
                                front: jax.Array, aff: jax.Array,
                                hub_mask: jax.Array, plan: RelaxPlan,
                                sweeps: int = 1):
    """`repair_chunk_frontier` with the key2 plane donated."""
    full = lambda c: repair_step(plan, g_new, c, aff, hub_mask)
    masked = lambda c, rows_g: repair_step_rows(rows_g, c, aff, hub_mask)
    out = cur
    for _ in range(sweeps):
        out, front = frontier_wave(plan, g_new, full, masked, out, front)
    return out, front, jnp.any(front)


# --- fused chunk variants (one dispatch per pipeline phase boundary) -------
#
# The unfused pipeline pays one dispatch for the seed plus one per chunk,
# and every chunk re-reads its input labelling plane from a fresh buffer.
# The fused variants collapse the seed→first-K-sweeps prefix of each
# fixpoint into a single executable and *donate* the labelling plane
# (`best` / `cur`) on every subsequent chunk, so XLA updates it in place
# instead of allocating per chunk. Donation contract (DESIGN.md §7): a
# donated plane is invalid the moment the chunk is dispatched — callers
# must rebind to the chunk's output and never touch the old reference
# (the pipeline loop below does exactly that; `tests/test_pipeline.py`
# runs every fused update twice and compares to prove no freed buffer is
# ever read). The first chunk is safe to donate *because* it is fused
# with the seed: the unfused pipeline's first chunk receives `best` and
# `seed` as the same buffer (donating it would invalidate `seed`, which
# later chunks still read), while `fused_search_start` returns `best` as
# a fresh output buffer distinct from `seed`.

@partial(jax.jit, static_argnames=("improved", "sweeps"))
def fused_search_start(g_new: Graph, batch: BatchUpdate, dist: jax.Array,
                       hub: jax.Array, landmarks: jax.Array,
                       plan: RelaxPlan | None, improved: bool = True,
                       sweeps: int = 1):
    """Seed + first `sweeps` search waves in ONE dispatch.

    Returns (best, seed, seeded, bound, hub_mask, changed). Convergence
    flag semantics match the unfused seed-then-chunk pair: the fixpoint
    is monotone, so `best == seed` after `sweeps` waves means settled.
    """
    check_labelling_width(g_new, dist)
    hub_mask = per_plane_hub_mask(landmarks, landmarks, g_new.n)
    if improved:
        seed, seeded, bound = search_improved_seed(g_new, batch, dist, hub,
                                                   hub_mask)
    else:
        seed, seeded = search_basic_seed(g_new, batch, dist)
        bound = dist
    best = seed
    for _ in range(sweeps):
        if improved:
            best = search_improved_step(plan, g_new, best, seed, bound,
                                        hub_mask)
        else:
            best = search_basic_step(plan, g_new, best, seed, bound)
    return best, seed, seeded, bound, hub_mask, jnp.any(best != seed)


@partial(jax.jit, static_argnames=("improved", "sweeps"), donate_argnums=(1,))
def fused_search_chunk(g_new: Graph, best: jax.Array, seed: jax.Array,
                       bound: jax.Array, hub_mask: jax.Array,
                       plan: RelaxPlan | None, improved: bool = True,
                       sweeps: int = 1) -> tuple[jax.Array, jax.Array]:
    """`search_chunk` with the labelling plane donated (updated in place
    on backends that honor donation; a perf no-op where they don't)."""
    cur = best
    for _ in range(sweeps):
        if improved:
            cur = search_improved_step(plan, g_new, cur, seed, bound,
                                       hub_mask)
        else:
            cur = search_basic_step(plan, g_new, cur, seed, bound)
    return cur, jnp.any(cur != best)


@partial(jax.jit, static_argnames=("sweeps",))
def fused_repair_start_chunk(g_new: Graph, aff: jax.Array, dist: jax.Array,
                             hub: jax.Array, hub_mask: jax.Array,
                             plan: RelaxPlan | None, sweeps: int = 1
                             ) -> tuple[jax.Array, jax.Array]:
    """Algo-4 boundary seeding + first `sweeps` interior waves in ONE
    dispatch → (cur, changed); returns a fresh `cur` safe to donate."""
    cur0 = repair_base(plan, g_new, aff, key2_make(dist, hub), hub_mask)
    cur = cur0
    for _ in range(sweeps):
        cur = repair_step(plan, g_new, cur, aff, hub_mask)
    return cur, jnp.any(cur != cur0)


@partial(jax.jit, static_argnames=("sweeps",), donate_argnums=(1,))
def fused_repair_chunk(g_new: Graph, cur: jax.Array, aff: jax.Array,
                       hub_mask: jax.Array, plan: RelaxPlan | None,
                       sweeps: int = 1) -> tuple[jax.Array, jax.Array]:
    """`repair_chunk` with the key2 plane donated."""
    out = cur
    for _ in range(sweeps):
        out = repair_step(plan, g_new, out, aff, hub_mask)
    return out, jnp.any(out != cur)


@jax.jit
def update_finish(aff: jax.Array, settled: jax.Array, dist: jax.Array,
                  hub: jax.Array, landmarks: jax.Array) -> HighwayLabelling:
    """Merge repaired keys into the labelling (dist/hub/highway)."""
    new_key2 = repair_merge(aff, settled, key2_make(dist, hub))
    ndist = jnp.minimum(key2_dist(new_key2), INF_D)
    nhub = key2_hub(new_key2) & (ndist < INF_D)
    highway = ndist[:, landmarks]
    return HighwayLabelling(landmarks, ndist, nhub, highway)


# ---------------------------------------------------------------------------
# The pipelined update
# ---------------------------------------------------------------------------

def pipelined_update(snapshot: Snapshot, batch: BatchUpdate, *,
                     plan: RelaxPlan | None = None,
                     g_new: Graph | None = None, mesh=None,
                     improved: bool = True, chunk_sweeps: int = 1,
                     fused: bool = False):
    """BatchHL update against `snapshot` as a generator of bounded
    dispatches; returns (snapshot N+1, aff[R, V]) via StopIteration.

    Yields a phase tag after *dispatching* each chunk and syncs on the
    chunk's `changed` flag only after resuming — the caller serves query
    microbatches against the committed snapshot at every yield, and each
    enqueues behind at most one chunk (`chunk_sweeps` relaxation waves)
    on the device queue. Like `batchhl_update`, a Pallas `plan` must be
    prepared from the post-update snapshot (pass the materialized graph
    as `g_new` to skip the recompute). With `mesh`, chunks run through
    the `core/shard.py` wrappers on the maintenance plane grouping.

    `fused=True` runs the megakernel chunk variants: each phase's
    seed + first K sweeps fuse into one dispatch, and subsequent chunks
    donate the labelling plane so sweeps update it in place (same phase
    tags, same bit-identical result — the fused-parity tests pin it).

    Drive it to completion with `run_pipelined_update`, or manually:

        gen = pipelined_update(snap, batch, plan=plan)
        for _phase in gen:
            serve_pending_queries()      # interleaved work goes here
        # StopIteration.value is the (snapshot, aff) result
    """
    if mesh is None:
        seed_fn = search_seed
        chunk_fn = fused_search_chunk if fused else search_chunk
        fstart_fn = fused_search_start
        rstart_fn = repair_start
        rchunk_fn = fused_repair_chunk if fused else repair_chunk
        frstart_fn = fused_repair_start_chunk
        finish_fn = update_finish
        f_seed_blocks = frontier_seed_blocks
        f_chunk_fn = (fused_search_chunk_frontier if fused
                      else search_chunk_frontier)
        f_fstart_fn = fused_search_start_frontier
        f_rstart_fn = repair_start_frontier
        f_rchunk_fn = (fused_repair_chunk_frontier if fused
                       else repair_chunk_frontier)
        f_frstart_fn = fused_repair_start_chunk_frontier
    else:
        from repro.core import shard
        seed_fn = partial(shard.shard_search_seed, mesh)
        chunk_fn = partial(shard.shard_fused_search_chunk if fused
                           else shard.shard_search_chunk, mesh)
        fstart_fn = partial(shard.shard_fused_search_start, mesh)
        rstart_fn = partial(shard.shard_repair_start, mesh)
        rchunk_fn = partial(shard.shard_fused_repair_chunk if fused
                            else shard.shard_repair_chunk, mesh)
        frstart_fn = partial(shard.shard_fused_repair_start_chunk, mesh)
        finish_fn = partial(shard.shard_update_finish, mesh)
        f_seed_blocks = frontier_seed_blocks
        f_chunk_fn = partial(shard.shard_fused_search_chunk_frontier if fused
                             else shard.shard_search_chunk_frontier, mesh)
        f_fstart_fn = partial(shard.shard_fused_search_start_frontier, mesh)
        f_rstart_fn = partial(shard.shard_repair_start_frontier, mesh)
        f_rchunk_fn = partial(shard.shard_fused_repair_chunk_frontier if fused
                              else shard.shard_repair_chunk_frontier, mesh)
        f_frstart_fn = partial(shard.shard_fused_repair_start_chunk_frontier,
                               mesh)

    lab = snapshot.labelling
    if g_new is None:
        g_new = apply_batch(snapshot.graph, batch)
    # Seeds must cross deletion/re-weight edges at their pre-update weight
    # (see coo.resolve_seed_weights); apply_batch above already consumed
    # the original post-update weights.
    batch = resolve_seed_weights(snapshot.graph, batch)

    if use_frontier(plan, g_new):
        # Frontier mode (DESIGN.md §10): swap in the chunk twins that
        # thread the changed-block bitmap, closing over it so the driver
        # below (and its yield discipline) stays identical. The bitmap is
        # chunk-carried state like `best`/`cur`, never surfaced to
        # callers.
        fr = {"front": None}
        base_seed_fn, base_fstart_fn = seed_fn, fstart_fn

        def seed_fn(g, b, dist, hub, lms, improved):
            seed, seeded, bound, hub_mask = base_seed_fn(
                g, b, dist, hub, lms, improved=improved)
            fr["front"] = f_seed_blocks(plan, seeded)
            return seed, seeded, bound, hub_mask

        def chunk_fn(g, best, seed, bound, hub_mask, plan_, improved,
                     sweeps):
            best, fr["front"], changed = f_chunk_fn(
                g, best, fr["front"], seed, bound, hub_mask, plan_,
                improved=improved, sweeps=sweeps)
            return best, changed

        def fstart_fn(g, b, dist, hub, lms, plan_, improved, sweeps):
            (best, fr["front"], seed, seeded, bound, hub_mask,
             changed) = f_fstart_fn(g, b, dist, hub, lms, plan_,
                                    improved=improved, sweeps=sweeps)
            return best, seed, seeded, bound, hub_mask, changed

        def rstart_fn(g, aff, dist, hub, hub_mask, plan_):
            cur, fr["front"] = f_rstart_fn(g, aff, dist, hub, hub_mask,
                                           plan_)
            return cur

        def rchunk_fn(g, cur, aff, hub_mask, plan_, sweeps):
            cur, fr["front"], changed = f_rchunk_fn(
                g, cur, fr["front"], aff, hub_mask, plan_, sweeps=sweeps)
            return cur, changed

        def frstart_fn(g, aff, dist, hub, hub_mask, plan_, sweeps):
            cur, fr["front"], changed = f_frstart_fn(
                g, aff, dist, hub, hub_mask, plan_, sweeps=sweeps)
            return cur, changed

    if fused:
        best, seed, seeded, bound, hub_mask, changed = fstart_fn(
            g_new, batch, lab.dist, lab.hub, lab.landmarks, plan,
            improved=improved, sweeps=chunk_sweeps)
    else:
        seed, seeded, bound, hub_mask = seed_fn(
            g_new, batch, lab.dist, lab.hub, lab.landmarks,
            improved=improved)
        best, changed = seed, True
    yield "search-seed"
    while bool(changed):
        # A donated `best` (fused path) is dead after this dispatch; the
        # rebind below is the only reference kept.
        best, changed = chunk_fn(g_new, best, seed, bound, hub_mask, plan,
                                 improved=improved, sweeps=chunk_sweeps)
        yield "search"
    aff = search_finish(best, seeded, improved=improved)

    if fused:
        cur, changed = frstart_fn(g_new, aff, lab.dist, lab.hub, hub_mask,
                                  plan, sweeps=chunk_sweeps)
    else:
        cur = rstart_fn(g_new, aff, lab.dist, lab.hub, hub_mask, plan)
        changed = True
    yield "repair-seed"
    while bool(changed):
        cur, changed = rchunk_fn(g_new, cur, aff, hub_mask, plan,
                                 sweeps=chunk_sweeps)
        yield "repair"

    new_lab = finish_fn(aff, cur, lab.dist, lab.hub, lab.landmarks)
    return Snapshot(snapshot.version + 1, g_new, new_lab, plan), aff


def run_pipelined_update(gen) -> tuple[Snapshot, jax.Array]:
    """Drain a `pipelined_update` with no interleaved work.

    The synchronous-equivalence hook: tests drain the generator dry and
    compare the committed snapshot bit-for-bit against `batchhl_update`.
    """
    while True:
        try:
            next(gen)
        except StopIteration as stop:
            return stop.value


# ---------------------------------------------------------------------------
# Full-state checkpointing (graph + labelling + version)
# ---------------------------------------------------------------------------

def snapshot_state(snap: Snapshot) -> dict:
    """The restartable serve state as a flat checkpoint tree.

    Includes the graph topology slots — a labelling alone cannot resume a
    serve loop (no edge set to apply the next batch to, no capacity). The
    `RelaxPlan` is derived state and deliberately excluded: the engine
    re-prepares it from the restored graph.
    """
    g, lab = snap.graph, snap.labelling
    return {
        "version": np.int64(snap.version),
        "n": np.int64(g.n),
        "graph_src": g.src, "graph_dst": g.dst, "graph_valid": g.valid,
        "graph_w": g.w,
        "landmarks": lab.landmarks, "dist": lab.dist, "hub": lab.hub,
        "highway": lab.highway,
    }


def save_snapshot(ckpt_dir: str, snap: Snapshot,
                  extra: dict | None = None) -> str:
    """Atomically persist the full serve state as step_<version>.

    `extra` adds caller-owned host state to the same atomic checkpoint
    (the serve loop stores its incremental edge list there — deletion
    sampling is edge-*order* dependent, so the order itself is state).
    """
    state = snapshot_state(snap)
    for k, v in (extra or {}).items():
        if k in state:
            raise ValueError(f"extra key {k!r} collides with snapshot state")
        state[k] = v
    return ckpt.save(ckpt_dir, snap.version, state)


def restore_extra(ckpt_dir: str, names: tuple[str, ...],
                  step: int | None = None) -> dict:
    """Load caller-owned `extra` leaves saved alongside a snapshot."""
    step = step if step is not None else ckpt.latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    return ckpt.load_leaves(ckpt_dir, step, names)


def publish_snapshot(ckpt_dir: str, snap: Snapshot,
                     extra: dict | None = None) -> str:
    """`save_snapshot` + flip the CURRENT pointer to it, durably.

    The replica updater's commit path (DESIGN.md §9): the step's leaves
    are fsync'd and renamed *before* the pointer flip, so a reader that
    observes the new CURRENT can always map the snapshot it names.
    """
    path = save_snapshot(ckpt_dir, snap, extra=extra)
    ckpt.publish(ckpt_dir, snap.version)
    return path


def restore_snapshot(ckpt_dir: str, step: int | None = None,
                     mmap: bool = False) -> Snapshot:
    """Rebuild a `Snapshot` from the newest (or given) checkpoint.

    Self-describing: shapes and the static vertex count come from the
    checkpoint itself, so no template tree is needed. The returned
    snapshot has `plan=None` — prepare one with the serving engine.

    `mmap=True` maps the arrays copy-free on the host (the replica
    readers' path — N readers of one published labelling share one
    page-cache copy); the device transfer, if any, is the backend's.
    """
    step = step if step is not None else ckpt.latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = ckpt.step_dir(ckpt_dir, step)

    core = ("graph_src", "graph_dst", "graph_valid", "graph_w", "n",
            "landmarks", "dist", "hub", "highway", "version")
    try:
        leaves = ckpt.load_leaves(ckpt_dir, step, core, mmap=mmap)
    except FileNotFoundError as e:
        missing = [k for k in ("graph_src", "graph_dst", "graph_valid")
                   if not os.path.exists(os.path.join(d, k + ".npy"))]
        if missing:
            raise FileNotFoundError(
                f"checkpoint {d} lacks graph state {missing}: it predates "
                "the full-state format and cannot resume a serve loop") \
                from e
        if not os.path.exists(os.path.join(d, "graph_w.npy")):
            raise UnweightedCheckpointError(
                f"checkpoint {d} lacks the edge-weight column graph_w: it "
                "predates the weighted-metric format. Re-serve from the "
                "original stream (or re-save the snapshot) to migrate; the "
                "weight column cannot be reconstructed from topology "
                "alone.") from e
        raise

    g = Graph(jnp.asarray(leaves["graph_src"]),
              jnp.asarray(leaves["graph_dst"]),
              jnp.asarray(leaves["graph_valid"]),
              jnp.asarray(leaves["graph_w"]), int(leaves["n"]))
    lab = HighwayLabelling(jnp.asarray(leaves["landmarks"]),
                           jnp.asarray(leaves["dist"]),
                           jnp.asarray(leaves["hub"]),
                           jnp.asarray(leaves["highway"]))
    return Snapshot(int(leaves["version"]), g, lab, None)


# ---------------------------------------------------------------------------
# Self-test (runnable under a forced multi-device host platform)
# ---------------------------------------------------------------------------

def _selftest() -> None:
    """Pipelined-vs-monolithic bit-parity on every host-mesh factorization
    × both sweep backends, then a pipelined ServeLoop whose every answer
    is re-derived synchronously at the version it was served.

    Run with a forced device count to exercise real multi-device meshes:

        XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
            PYTHONPATH=src python -m repro.core.snapshot
    """
    from repro.graphs import generators as gen
    from repro.graphs.coo import from_edges, make_batch
    from repro.core.construct import build_labelling, \
        select_landmarks_by_degree
    from repro.core.batch import batchhl_update
    from repro.core.engine import RelaxEngine
    from repro.core.query import batched_query
    from repro.launch.mesh import make_host_mesh
    from repro.launch.serve import ServeConfig, ServeLoop

    n_dev = len(jax.devices())
    n, r = 120, 8
    edges = gen.random_connected(n, extra_edges=150, seed=3)
    g = from_edges(n, edges, edges.shape[0] + 64)
    landmarks = select_landmarks_by_degree(g, r)
    lab0 = build_labelling(g, landmarks)
    ups = gen.random_batch_updates(edges, n, n_ins=6, n_del=6, seed=9)
    batch = make_batch(ups, pad_to=12)
    g1, lab1, aff1 = batchhl_update(g, batch, lab0, improved=True)

    g1_host = apply_batch(g, batch)
    engine = RelaxEngine(backend="pallas", block_v=32, shards=2)
    plan1 = engine.prepare(g1_host)

    for model in [m for m in (1, 2, 4, 8) if n_dev % m == 0]:
        mesh = make_host_mesh(model=model)
        for backend, pln in (("jnp", None), ("pallas", plan1)):
            for fused in (False, True):
                snap = Snapshot(0, g, lab0, pln)
                nxt, aff = run_pipelined_update(pipelined_update(
                    snap, batch, plan=pln, mesh=mesh, chunk_sweeps=2,
                    fused=fused))
                np.testing.assert_array_equal(np.asarray(aff),
                                              np.asarray(aff1))
                for f in ("dist", "hub", "highway"):
                    np.testing.assert_array_equal(
                        np.asarray(getattr(nxt.labelling, f)),
                        np.asarray(getattr(lab1, f)))
                print(f"mesh (data={mesh.shape['data']}, model={model}) "
                      f"backend={backend} fused={fused}: "
                      f"pipelined update bit-parity OK")

    # End-to-end: pipelined serving on a real mesh (if the device count
    # allows a model axis), every answer checked at its served version.
    shards = 2 if n_dev % 2 == 0 and n_dev > 1 else 1
    for backend in ("jnp", "pallas"):
        cfg = ServeConfig(n=200, deg=3, landmarks=8, batches=2,
                          batch_size=20, queries=24, qps=5000.0,
                          microbatch=8, pipeline=True, backend=backend,
                          block_v=64, tile_shards=2, mesh="host",
                          shards=shards, quiet=True, keep_history=True)
        rep = ServeLoop(cfg).run()
        for m in rep.microbatches:
            s = rep.history[m.version]
            want = batched_query(s.graph, s.labelling,
                                 jnp.asarray(m.qs), jnp.asarray(m.qt))
            np.testing.assert_array_equal(m.answers, np.asarray(want))
        assert any(m.staleness == 1 for m in rep.microbatches), \
            "no query overlapped an update — pipeline never engaged"
        print(f"serve pipeline backend={backend} (mesh shards={shards}): "
              f"{len(rep.microbatches)} microbatches exact at their "
              f"versions")
    print(f"pipeline selftest OK on {n_dev} device(s)")


if __name__ == "__main__":
    _selftest()
