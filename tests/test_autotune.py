"""Autotuner cache contract: the tuning table is keyed by snapshot
*shape* and round-trips through disk.

Three behaviors pin the contract (DESIGN.md §7):

  * persistence — a table written by one engine reloads into a fresh
    engine and yields the same plan with **zero** re-tunes (the serve
    restart path behind `--tune-table`);
  * shape sensitivity — edge churn at fixed (n, capacity, shards) reuses
    the winner, while `coo.grow` / `grow_snapshot` change the key and
    force a fresh measurement (the same staleness class the PR 5
    fingerprint guards at the plan level);
  * LRU interaction — the serving pipeline's two-live-snapshot pattern
    (committed N answering queries, N+1 under construction) alternates
    prepares without ever re-tuning or retiling.

Off-TPU the candidate space is the single `sorted` config, so these run
in the fast job: each tune() is two small jit compilations.
"""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.core import autotune as at
from repro.core.construct import build_labelling, select_landmarks_by_degree
from repro.core.engine import RelaxEngine
from repro.core.snapshot import Snapshot, grow_snapshot
from repro.graphs import generators as gen
from repro.graphs.coo import apply_batch, from_edges, grow, make_batch


def _graph(n=90, extra=80, slack=40, seed=5):
    edges = gen.random_connected(n, extra_edges=extra, seed=seed)
    return from_edges(n, edges, edges.shape[0] + slack), edges


# --- measurement discipline -------------------------------------------------

def test_measure_compiled_call_accounting():
    """First call timed apart as compile, `warmup` discarded, steady =
    min over `iters` — so 1 + warmup + iters calls total."""
    calls = []

    def fn(x):
        calls.append(1)
        return np.asarray(x) + 1

    compile_us, steady_us = at.measure_compiled(fn, 3, warmup=2, iters=4)
    assert len(calls) == 1 + 2 + 4
    assert compile_us >= 0 and steady_us >= 0


def test_tune_returns_winner_from_candidate_space():
    g, _ = _graph(n=60, extra=40, slack=20)
    res = at.tune(g, shards=2, block_v=32, include_kernel=False, iters=2)
    assert res.config == at.TuneConfig("sorted", 32, None, 2)
    assert res.steady_us > 0 and res.jnp_us > 0 and res.compile_us > 0
    assert [c for c, _, _ in res.candidates] == [res.config]


# --- table round-trip: persist → reload → same plan, zero re-tune -----------

def test_table_roundtrip_zero_retune(tmp_path):
    g, _ = _graph()
    path = str(tmp_path / "tuning.json")

    e1 = RelaxEngine(backend="pallas", block_v=32, shards=2,
                     autotune=True, tune_table=path)
    p1 = e1.prepare(g)
    assert e1.tune_count == 1
    assert p1.impl == "sorted" and p1.sorted_tiles is not None  # off-TPU

    # the table hit disk atomically, in the documented schema
    with open(path) as f:
        doc = json.load(f)
    key = at.table_key(g.n, g.src.shape[0], 2)
    assert doc["version"] == 1 and key in doc["entries"]
    assert doc["entries"][key]["config"] == e1._tuned_cfg.to_dict()

    # a fresh engine reloads the table: same plan, zero measurement runs
    e2 = RelaxEngine(backend="pallas", block_v=32, shards=2,
                     autotune=True, tune_table=path)
    p2 = e2.prepare(g)
    assert e2.tune_count == 0, "table reload must skip the tuner entirely"
    assert p2.impl == p1.impl
    np.testing.assert_array_equal(np.asarray(p2.sorted_tiles.perm_s),
                                  np.asarray(p1.sorted_tiles.perm_s))
    # and the standalone table API round-trips the config
    assert at.TuneTable(path).get(key) == e2._tuned_cfg == e1._tuned_cfg


def test_edge_churn_at_fixed_shape_reuses_winner():
    """Applying a batch (same n, same capacity) must not re-tune: the
    table keys shape, the plan cache keys content."""
    g, edges = _graph()
    ups = gen.random_batch_updates(edges, g.n, n_ins=6, n_del=6, seed=9)
    g2 = apply_batch(g, make_batch(ups, pad_to=12))
    assert g2.src.shape[0] == g.src.shape[0]

    e = RelaxEngine(backend="pallas", block_v=32, shards=2, autotune=True)
    e.prepare(g)
    e.prepare(g2)
    assert e.tune_count == 1
    assert e.retile_count == 2  # different content: two plans, one tune
    assert len(e.tune_table) == 1


# --- fingerprint sensitivity: grown shapes must re-tune ---------------------

def test_grow_changes_table_key_and_retunes():
    g, _ = _graph()
    e = RelaxEngine(backend="pallas", block_v=32, shards=2, autotune=True)
    e.prepare(g)
    assert e.tune_count == 1

    g_cap = grow(g, capacity=g.src.shape[0] + 64)
    e.prepare(g_cap)
    assert e.tune_count == 2, "grown capacity must force a fresh tune"

    g_n = grow(g_cap, n=g.n + 32)
    e.prepare(g_n)
    assert e.tune_count == 3, "grown n must force a fresh tune"

    keys = {at.table_key(x.n, x.src.shape[0], 2) for x in (g, g_cap, g_n)}
    assert len(keys) == 3 and set(e.tune_table.entries) == keys


def test_grow_snapshot_retunes():
    g, _ = _graph(n=70, extra=50, slack=24)
    lab = build_labelling(g, select_landmarks_by_degree(g, 4))
    e = RelaxEngine(backend="pallas", block_v=32, shards=1, autotune=True)
    e.prepare(g)
    snap = grow_snapshot(Snapshot(0, g, lab, None),
                         capacity=g.src.shape[0] + 48, n=g.n + 2)
    e.prepare(snap.graph)
    assert e.tune_count == 2
    assert len(e.tune_table) == 2


# --- LRU interaction: the two-live-snapshot serve pattern -------------------

def test_two_live_snapshots_alternate_without_retuning():
    """Committed-N / building-N+1 alternation (PR 4's serve case): the
    keyed plan cache absorbs the alternation and the tuner never runs
    again — one measurement amortizes over the whole stream."""
    g, edges = _graph()
    ups = gen.random_batch_updates(edges, g.n, n_ins=5, n_del=5, seed=2)
    g2 = apply_batch(g, make_batch(ups, pad_to=10))

    e = RelaxEngine(backend="pallas", block_v=32, shards=2, autotune=True,
                    cache_plans=2)
    pa = e.prepare(g)
    pb = e.prepare(g2)
    assert e.tune_count == 1 and e.retile_count == 2
    pa2 = e.prepare(g)
    pb2 = e.prepare(g2)
    assert e.retile_count == 2, "keyed cache missed a live snapshot"
    assert e.plan_cache_hits == 2 and e.tune_count == 1
    assert pa2.sorted_tiles is pa.sorted_tiles
    assert pb2.sorted_tiles is pb.sorted_tiles


def test_lru_eviction_respects_tuned_key():
    """Evicting past capacity still re-tunes zero times for known shapes,
    and the cache key carries the tuned config — a plan prepared under
    one winner can never be served for another."""
    g, edges = _graph()
    ups = gen.random_batch_updates(edges, g.n, n_ins=4, n_del=4, seed=3)
    g2 = apply_batch(g, make_batch(ups, pad_to=8))
    ups2 = gen.random_batch_updates(edges, g.n, n_ins=3, n_del=3, seed=4)
    g3 = apply_batch(g, make_batch(ups2, pad_to=8))

    e = RelaxEngine(backend="pallas", block_v=32, shards=2, autotune=True,
                    cache_plans=2)
    for snap in (g, g2, g3):          # 3 same-shape snapshots, capacity 2
        e.prepare(snap)
    assert e.tune_count == 1
    assert e.retile_count == 3
    e.prepare(g)                      # evicted → retile, still no re-tune
    assert e.retile_count == 4 and e.tune_count == 1
