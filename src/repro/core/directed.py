"""Directed-graph BatchHL (paper §6, Table 6).

Two labelling planes are maintained:
  * forward  L_f[r, v] = δ(r → v)  — wave relaxation along arcs,
  * backward L_b[r, v] = δ(v → r)  — relaxation along reversed arcs,
with forward/backward highways H_f = H_bᵀ. A query (s, t) combines
    d⊤ = min_{i,j}  L_b[i, s] + H_f[i, j] + L_f[j, t]
with a distance-bounded directed bidirectional search (forward from s,
backward from t) on G[V \\ R].

Updates: an arc (a→b) only creates/destroys paths entering through b on the
forward plane (and through a on the backward plane), so the anchor is fixed
per plane — a one-sided specialization of the paper's anchor rule. Batch
search/repair then run unchanged on the corresponding edge orientation.

Storage: one padded arc table (src, dst, valid) holds each arc once; the
backward plane relaxes it with src/dst swapped. `apply_batch_directed`
matches deletions exactly (no undirected canonicalization).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.graphs.coo import Graph, BatchUpdate, INF_D
from repro.core.labelling import (
    HighwayLabelling, INF_KEY2, INF_KEY4, key2_dist, key2_hub,
    key4_from_key2, key4_extend, key4_beta,
)
from repro.core.batch import (_per_plane_hub_mask, _fixpoint, batch_repair)
from repro.core.engine import RelaxPlan, relax_sweep
from repro.core.construct import build_labelling


@partial(jax.tree_util.register_dataclass,
         data_fields=("src", "dst", "valid", "w"), meta_fields=("n",))
@dataclasses.dataclass(frozen=True)
class DirectedGraph:
    src: jax.Array    # int32[cap] arc tails
    dst: jax.Array    # int32[cap] arc heads
    valid: jax.Array  # bool[cap]
    w: jax.Array      # int32[cap] arc weight; 0 on free slots
    n: int

    def fwd(self) -> Graph:
        return Graph(self.src, self.dst, self.valid, self.w, self.n)

    def rev(self) -> Graph:
        return Graph(self.dst, self.src, self.valid, self.w, self.n)


def from_arcs(n: int, arcs: np.ndarray, capacity: int) -> DirectedGraph:
    """[m, 2] arcs (unit weight) or [m, 3] (tail, head, weight) rows."""
    arcs = np.asarray(arcs, np.int32)
    arcs = (arcs.reshape(-1, 2) if arcs.ndim < 2 or arcs.shape[1] == 2
            else arcs.reshape(-1, 3))
    m = arcs.shape[0]
    if m > capacity:
        raise ValueError(f"{m} arcs exceed capacity {capacity}")
    src = np.zeros(capacity, np.int32)
    dst = np.zeros(capacity, np.int32)
    valid = np.zeros(capacity, bool)
    w = np.zeros(capacity, np.int32)
    src[:m], dst[:m] = arcs[:, 0], arcs[:, 1]
    w[:m] = arcs[:, 2] if arcs.shape[1] == 3 else 1
    valid[:m] = True
    return DirectedGraph(jnp.asarray(src), jnp.asarray(dst),
                         jnp.asarray(valid), jnp.asarray(w), n)


def apply_batch_directed(g: DirectedGraph, b: BatchUpdate) -> DirectedGraph:
    """Exact-arc deletion + in-place re-weight + free-slot insertion."""
    del_mask = b.is_del & b.valid
    d_src = jnp.where(del_mask, b.src, -1)
    d_dst = jnp.where(del_mask, b.dst, -1)
    hit = jnp.any((g.src[:, None] == d_src[None, :])
                  & (g.dst[:, None] == d_dst[None, :]), axis=1)
    valid = g.valid & ~hit
    w = jnp.where(hit, 0, g.w)   # freed slots drop their weight

    rew_mask = b.is_rew & b.valid
    r_src = jnp.where(rew_mask, b.src, -1)
    r_dst = jnp.where(rew_mask, b.dst, -1)
    rhit = ((g.src[:, None] == r_src[None, :])
            & (g.dst[:, None] == r_dst[None, :]))            # [cap, U]
    rrow = jnp.argmax(rhit, axis=1)
    rany = jnp.any(rhit, axis=1) & valid
    w = jnp.where(rany, b.w[rrow], w)

    ins_mask = (~b.is_del) & (~b.is_rew) & b.valid
    u = b.src.shape[0]
    free_idx = jnp.nonzero(~valid, size=u, fill_value=valid.shape[0] - 1)[0]
    rank = jnp.cumsum(ins_mask) - 1
    slot = free_idx[jnp.clip(rank, 0, u - 1)]
    oob = jnp.int32(g.src.shape[0])
    slot = jnp.where(ins_mask, slot, oob)
    src = g.src.at[slot].set(b.src, mode="drop")
    dst = g.dst.at[slot].set(b.dst, mode="drop")
    valid = valid.at[slot].set(True, mode="drop")
    w = w.at[slot].set(b.w, mode="drop")
    return DirectedGraph(src, dst, valid, w, g.n)


def resolve_seed_weights_directed(g_old: DirectedGraph,
                                  b: BatchUpdate) -> BatchUpdate:
    """Directed twin of `coo.resolve_seed_weights`: exact-arc matching.

    Deletions seed at the arc's pre-update weight, re-weights at
    min(old, new) — the superset-safe seed either way; insertions keep
    the batch's (new) weight.
    """
    need_old = (b.is_del | b.is_rew) & b.valid
    bs = jnp.where(need_old, b.src, -1)
    bd = jnp.where(need_old, b.dst, -1)
    m = ((bs[:, None] == g_old.src[None, :])
         & (bd[:, None] == g_old.dst[None, :])
         & g_old.valid[None, :])                              # [U, cap]
    w_old = jnp.max(jnp.where(m, g_old.w[None, :], 0), axis=1)
    w_old = jnp.where(w_old == 0, 1, w_old)                   # unmatched
    w_eff = jnp.where(b.is_del, w_old,
                      jnp.where(b.is_rew, jnp.minimum(w_old, b.w), b.w))
    return dataclasses.replace(
        b, w=jnp.where(b.valid, w_eff, 1).astype(jnp.int32))


@partial(jax.tree_util.register_dataclass,
         data_fields=("fwd", "bwd"), meta_fields=())
@dataclasses.dataclass(frozen=True)
class DirectedLabelling:
    fwd: HighwayLabelling   # L_f, H_f (distances r → v)
    bwd: HighwayLabelling   # L_b, H_b (distances v → r)


def build_directed_labelling(g: DirectedGraph, landmarks: jax.Array,
                             plan_fwd: RelaxPlan | None = None,
                             plan_bwd: RelaxPlan | None = None
                             ) -> DirectedLabelling:
    """Both planes' labellings. The two arc orientations are two distinct
    topologies to the relaxation engine, so each takes its own plan:
    `plan_fwd` prepared on `g.fwd()`, `plan_bwd` on `g.rev()` (None runs
    the jnp reference, as everywhere)."""
    return DirectedLabelling(build_labelling(g.fwd(), landmarks,
                                             plan=plan_fwd),
                             build_labelling(g.rev(), landmarks,
                                             plan=plan_bwd))


def _directed_search(g_new: Graph, batch_src, batch_dst, batch_e,
                     batch_valid, batch_w, labelling: HighwayLabelling,
                     plan: RelaxPlan | None = None) -> jax.Array:
    """Improved batch search on one plane; anchors fixed at arc heads.

    `batch_e` is the key4 e-flag (deletion-like: deletions and re-weights,
    which can lengthen paths); `batch_w` the per-update seed weight
    (resolved by `resolve_seed_weights_directed`).
    """
    n = g_new.n
    dist_g = labelling.dist
    key2_g = labelling.key2()
    beta = key4_beta(key2_g)
    hub_mask = _per_plane_hub_mask(labelling, n)

    da = dist_g[:, batch_src]                                # [R, U] (pre)
    db = dist_g[:, batch_dst]
    # Arc a→b can only change paths through b; skip if it cannot shorten /
    # was not potentially on a shortest path at its seed weight
    # (superset-safe check; w ≡ 1 recovers the unweighted da+1 <= db).
    nontrivial = ((da + batch_w[None, :] <= db) & (da < INF_D)
                  & batch_valid[None, :])
    key2_pre = jnp.take_along_axis(key2_g, batch_src[None, :].repeat(
        dist_g.shape[0], 0), axis=1)
    k4 = key4_from_key2(key2_pre, batch_e[None, :])
    anchor_is_hub = jnp.take_along_axis(
        hub_mask, batch_dst[None, :].repeat(dist_g.shape[0], 0), axis=1)
    seed_k4 = key4_extend(k4, anchor_is_hub, w=batch_w[None, :])
    seed_k4 = jnp.where(nontrivial, seed_k4, INF_KEY4)

    def scatter_seeds(vals):
        plane = jnp.full((n,), INF_KEY4, jnp.int32)
        return plane.at[batch_dst].min(vals)
    seed = jax.vmap(scatter_seeds)(seed_k4)
    seeded = seed < INF_KEY4

    def plane_fix(seed_p, beta_p, hub_p):
        def sweep(best):
            # key4_extend per arc, routed through the engine: +4, clamp,
            # clear the l-bit at hub heads — same dispatch as the
            # undirected Algo-3 step, so `plan` selects jnp vs Pallas.
            cand = relax_sweep(plan, g_new, best, 4, INF_KEY4,
                               hub=hub_p, clear_bit=2)
            cand = jnp.where(cand <= beta_p, cand, INF_KEY4)
            return jnp.minimum(best, jnp.minimum(cand, seed_p))
        return _fixpoint(sweep, seed_p)

    best = jax.vmap(plane_fix)(seed, beta, hub_mask)
    return seeded | (best < INF_KEY4)


@jax.jit
def batchhl_update_directed(g: DirectedGraph, batch: BatchUpdate,
                            lab: DirectedLabelling,
                            plan_fwd: RelaxPlan | None = None,
                            plan_bwd: RelaxPlan | None = None
                            ) -> tuple[DirectedGraph, DirectedLabelling,
                                       jax.Array]:
    """One directed BatchHL step: both planes searched + repaired.

    Like the undirected `batchhl_update`, plans must be prepared from the
    *post-update* snapshot — `plan_fwd` on `apply_batch_directed(g,
    batch).fwd()`, `plan_bwd` on its `.rev()` (the reversed orientation is
    a distinct topology to the tiler). None runs the jnp reference;
    `tests/test_directed_engine.py` pins backend bit-parity.
    """
    g2 = apply_batch_directed(g, batch)
    batch_res = resolve_seed_weights_directed(g, batch)
    e_flag = batch.is_del | batch.is_rew
    # forward plane: arcs as-is, anchor = head
    aff_f = _directed_search(g2.fwd(), batch.src, batch.dst, e_flag,
                             batch.valid, batch_res.w, lab.fwd, plan_fwd)
    new_f = batch_repair(g2.fwd(), aff_f, lab.fwd, plan_fwd)
    # backward plane: reversed arcs, anchor = tail
    aff_b = _directed_search(g2.rev(), batch.dst, batch.src, e_flag,
                             batch.valid, batch_res.w, lab.bwd, plan_bwd)
    new_b = batch_repair(g2.rev(), aff_b, lab.bwd, plan_bwd)
    return g2, DirectedLabelling(new_f, new_b), aff_f | aff_b


def directed_query(g: DirectedGraph, lab: DirectedLabelling, s: jax.Array,
                   t: jax.Array, max_steps: int = 64,
                   plan_fwd: RelaxPlan | None = None,
                   plan_bwd: RelaxPlan | None = None) -> jax.Array:
    """Exact directed distances d(s → t) for query batches.

    `plan_fwd`/`plan_bwd` route the bidirectional search's frontier
    expansions through the engine (forward waves follow arcs, backward
    waves the reversed orientation); None runs the jnp reference."""
    from repro.core.query import effective_labels
    from repro.core.labelling import landmark_onehot

    lb = effective_labels(lab.bwd)                           # δ(· → r_i)
    lf = effective_labels(lab.fwd)                           # δ(r_j → ·)
    s_lab = jnp.minimum(lb[:, s].T, INF_D)                   # [B, R]
    t_lab = jnp.minimum(lf[:, t].T, INF_D)
    mid = jnp.min(s_lab[:, :, None] + lab.fwd.highway[None, :, :], axis=1)
    d_top = jnp.minimum(jnp.min(mid + t_lab, axis=1), INF_D)

    # bounded directed bidirectional search on G[V \ R]
    n = g.n
    b = s.shape[0]
    blocked = landmark_onehot(lab.fwd.landmarks, n)
    inf = INF_D
    ds = jnp.full((b, n), inf, jnp.int32).at[jnp.arange(b), s].set(0)
    dt = jnp.full((b, n), inf, jnp.int32).at[jnp.arange(b), t].set(0)
    ds = jnp.where(blocked[s][:, None], inf, ds)
    dt = jnp.where(blocked[t][:, None], inf, dt)

    # Weighted termination bound, as in the undirected bounded_bibfs: a
    # path still unaccounted for after ls+lt waves has ≥ ls+lt+1 arcs.
    wmin = jnp.clip(jnp.min(jnp.where(g.valid, g.w, INF_D), initial=INF_D),
                    1, 1 << 20)

    def expand(dist_x, og, plan):
        # One Bellman-Ford wave over the whole plane — the same
        # engine-dispatched primitive (and kernel) as the undirected
        # bounded BiBFS; with w ≡ 1 it reproduces the level-synchronous
        # frontier expansion bit-identically.
        cand = jax.vmap(
            lambda k: relax_sweep(plan, og, k, 1, inf))(dist_x)
        cand = jnp.where(blocked[None, :], inf, cand)
        return jnp.minimum(dist_x, cand)

    def cond(state):
        ds, dt, ls, lt, fs, ft, best, step = state
        return (jnp.any((ls + lt + 1) * wmin < jnp.minimum(best, d_top))
                & (step < max_steps))

    def body(state):
        ds, dt, ls, lt, fs, ft, best, step = state
        exp_s = fs <= ft

        def s_side(a):
            ds, dt, ls, lt, fs, ft = a
            nd = expand(ds, g.fwd(), plan_fwd)
            return nd, dt, ls + 1, lt, jnp.sum(nd != ds), ft

        def t_side(a):
            ds, dt, ls, lt, fs, ft = a
            nd = expand(dt, g.rev(), plan_bwd)
            return ds, nd, ls, lt + 1, fs, jnp.sum(nd != dt)

        ds, dt, ls, lt, fs, ft = jax.lax.cond(exp_s, s_side, t_side,
                                              (ds, dt, ls, lt, fs, ft))
        best = jnp.minimum(best, jnp.min(jnp.minimum(ds + dt, inf), axis=1))
        return ds, dt, ls, lt, fs, ft, best, step + 1

    best0 = jnp.min(jnp.minimum(ds + dt, inf), axis=1)
    state = (ds, dt, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
             jnp.sum(ds == 0), jnp.sum(dt == 0),
             best0, jnp.zeros((), jnp.int32))
    *_, best, _ = jax.lax.while_loop(cond, body, state)
    out = jnp.minimum(best, d_top)
    return jnp.where(out >= INF_D, INF_D, out)
