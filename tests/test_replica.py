"""The replica serve tier, bottom-up: the router's QueryQueue policies
(admission control + microbatch coalescing) in isolation, the wire
protocol, the publish/ack barrier records, the ServeSpec config re-cut's
lossless round-trips — and the crash-recovery integration test: a reader
killed mid-stream, restarted from ``CURRENT``, with every answer checked
against the Dijkstra oracle *at the version it was served* and the
staleness ≤ 1 contract held across the process boundary (DESIGN.md §9).
"""
from __future__ import annotations

import os
import subprocess
import threading
import time
import warnings

import numpy as np
import pytest

from repro.checkpoint import manager as ckpt
from repro.launch import replica
from repro.launch.config import (EngineSpec, GraphSpec, ServeSpec,
                                 StreamSpec, TopologySpec, build_parser,
                                 spec_from_cli)
from repro.launch.replica import QueryQueue


# ---------------------------------------------------------------------------
# QueryQueue: admission control
# ---------------------------------------------------------------------------

def test_admission_counts_queries_not_requests():
    q = QueryQueue(max_pending=10, microbatch=32, coalesce_s=0.0)
    assert q.offer("a", 6)
    assert q.offer("b", 4)          # exactly at the cap
    assert q.pending == 10
    assert not q.offer("c", 1)      # one over: refused
    assert q.rejected == 1
    assert q.pending == 10          # refusal left the queue untouched


def test_admission_exempts_front_requeue():
    """A batch reclaimed from a dead reader re-enters at the head even
    when the queue is full — a reader crash must not surface as client
    rejections."""
    q = QueryQueue(max_pending=4, microbatch=32, coalesce_s=0.0)
    assert q.offer("a", 4)
    assert not q.offer("b", 1)
    assert q.offer("requeued", 3, front=True)
    assert q.pending == 7
    assert q.take() == ["requeued", "a"]  # head position preserved


# ---------------------------------------------------------------------------
# QueryQueue: coalescing
# ---------------------------------------------------------------------------

def test_coalesce_merges_up_to_microbatch():
    q = QueryQueue(max_pending=100, microbatch=8, coalesce_s=10.0)
    for name, m in (("a", 3), ("b", 3), ("c", 2), ("d", 1)):
        q.offer(name, m)
    # 3+3+2 fills the microbatch exactly; "d" stays for the next take —
    # and a full batch returns without waiting out the 10s window.
    t0 = time.monotonic()
    assert q.take() == ["a", "b", "c"]
    assert time.monotonic() - t0 < 5.0
    assert q.pending == 1


def test_coalesce_never_splits_entries():
    """Entries are whole client requests — each must be answered at one
    version, so the coalescer takes them entirely or not at all."""
    q = QueryQueue(max_pending=100, microbatch=8, coalesce_s=0.01)
    q.offer("a", 5)
    q.offer("b", 5)                  # 5+5 > 8: must not be split
    assert q.take() == ["a"]
    assert q.take() == ["b"]


def test_coalesce_dispatches_oversized_alone():
    q = QueryQueue(max_pending=100, microbatch=8, coalesce_s=0.01)
    q.offer("big", 20)               # admitted (<=max_pending), > microbatch
    q.offer("small", 1)
    assert q.take() == ["big"]       # oversized runs alone
    assert q.take() == ["small"]


def test_coalesce_window_closes_on_partial_batch():
    q = QueryQueue(max_pending=100, microbatch=32, coalesce_s=0.05)
    q.offer("a", 2)
    t0 = time.monotonic()
    assert q.take(timeout=5.0) == ["a"]
    assert time.monotonic() - t0 < 2.0   # window (50ms), not timeout (5s)


def test_take_empty_after_timeout():
    q = QueryQueue(max_pending=10, microbatch=8, coalesce_s=0.01)
    assert q.take(timeout=0.01) == []


def test_take_picks_up_late_arrivals_inside_window():
    q = QueryQueue(max_pending=100, microbatch=8, coalesce_s=0.5)
    got = []
    t = threading.Thread(target=lambda: got.extend(q.take(timeout=2.0)))
    q.offer("a", 2)
    t.start()
    time.sleep(0.05)
    q.offer("b", 2)                  # lands inside the open window
    t.join()
    assert got == ["a", "b"]


# ---------------------------------------------------------------------------
# Wire protocol
# ---------------------------------------------------------------------------

def test_query_answer_pack_roundtrip():
    qs = np.arange(5, dtype=np.int32)
    qt = np.arange(5, 10, dtype=np.int32)
    qs2, qt2 = replica.unpack_query(replica.pack_query(qs, qt))
    np.testing.assert_array_equal(qs, qs2)
    np.testing.assert_array_equal(qt, qt2)
    v, h, d = replica.unpack_answer(
        replica.pack_answer(7, 8, np.asarray([1, 2, 3], np.int32)))
    assert (v, h) == (7, 8)
    np.testing.assert_array_equal(d, [1, 2, 3])


# ---------------------------------------------------------------------------
# Publish/ack records (the barrier's inputs)
# ---------------------------------------------------------------------------

def test_publish_requires_saved_step(tmp_path):
    d = str(tmp_path)
    with pytest.raises(FileNotFoundError):
        ckpt.publish(d, 3)
    ckpt.save(d, 3, {"x": np.arange(4)})
    rec = ckpt.publish(d, 3)
    assert rec["version"] == 3
    assert ckpt.current_step(d) == 3


def test_prune_never_removes_published_step(tmp_path):
    d = str(tmp_path)
    for s in range(5):
        ckpt.save(d, s, {"x": np.arange(4) + s})
    ckpt.publish(d, 1)
    ckpt.prune(d, keep=2)
    assert ckpt.current_step(d) == 1
    assert ckpt.step_manifest(d, 1) is not None      # published: protected
    assert ckpt.step_manifest(d, 4) is not None      # newest: kept
    assert ckpt.step_manifest(d, 0) is None          # pruned


def test_ack_barrier_ignores_dead_readers(tmp_path):
    d = str(tmp_path)
    replica.write_ack(d, 0, version=5)               # live: this process
    # A pid that has definitely exited: a finished child.
    p = subprocess.Popen(["true"])
    p.wait()
    replica.write_ack(d, 1, version=0)
    acks = replica.read_acks(d)
    rec = dict(acks[1])
    rec["pid"] = p.pid
    ckpt.write_json_atomic(
        os.path.join(d, "acks", "reader_1.json"), rec)
    # Reader 1 is behind but dead — the barrier must not wait for it.
    assert replica.wait_for_acks(d, version=5, timeout_s=5.0)


def test_ack_barrier_times_out_on_live_laggard(tmp_path):
    d = str(tmp_path)
    replica.write_ack(d, 0, version=1)               # live (us), behind
    t0 = time.monotonic()
    assert not replica.wait_for_acks(d, version=2, timeout_s=0.1,
                                     log=lambda *a: None)
    assert time.monotonic() - t0 < 2.0


# ---------------------------------------------------------------------------
# ServeSpec round-trips (the config re-cut's losslessness contract)
# ---------------------------------------------------------------------------

def _nondefault_spec() -> ServeSpec:
    return ServeSpec(
        graph=GraphSpec(n=500, deg=3, landmarks=8, capacity=640, grow=True),
        engine=EngineSpec(backend="pallas", block_v=128, fused=True),
        stream=StreamSpec(batches=3, qps=123.5, pipeline=True, verify=True),
        topology=TopologySpec(readers=3, coalesce_ms=5.0, restart=True))


def test_spec_cli_roundtrip():
    spec = _nondefault_spec()
    ap = build_parser("t")
    ns = ap.parse_args(spec.to_args())
    assert ServeSpec.from_parsed_args(ns) == spec


def test_spec_json_roundtrip(tmp_path):
    spec = _nondefault_spec()
    path = str(tmp_path / "spec.json")
    spec.save_json(path)
    assert ServeSpec.load_json(path) == spec


def test_spec_serve_config_roundtrip():
    spec = _nondefault_spec()
    cfg = spec.to_serve_config()
    assert cfg.n == 500 and cfg.backend == "pallas" and cfg.qps == 123.5
    back = ServeSpec.from_serve_config(cfg, topology=spec.topology)
    assert back == spec


def test_flat_flags_alone_are_the_spec():
    ap = build_parser("t")
    ns = ap.parse_args(["--n", "700"])
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        spec = spec_from_cli(ns, ap)
    assert spec.graph.n == 700
    assert not w                     # flat-only: supported, no warning


def test_flat_overrides_alongside_config_warn_deprecated(tmp_path):
    path = str(tmp_path / "spec.json")
    _nondefault_spec().save_json(path)
    ap = build_parser("t")
    ns = ap.parse_args(["--config", path, "--n", "700"])
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        spec = spec_from_cli(ns, ap)
    assert spec.graph.n == 700               # flat flag overrode the JSON
    assert spec.engine.backend == "pallas"   # the rest came from the JSON
    assert any(issubclass(x.category, DeprecationWarning) for x in w)


def test_realized_n_road_rounds_to_grid():
    import math
    gs = GraphSpec(n=2025, graph="road")
    rows = max(2, math.isqrt(2025))
    assert gs.realized_n() == rows * max(2, (2025 + rows - 1) // rows)
    assert GraphSpec(n=2025).realized_n() == 2025


# ---------------------------------------------------------------------------
# Crash recovery: kill a reader mid-stream, restart from CURRENT,
# zero wrong answers at each answer's served version, staleness <= 1.
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_reader_crash_recovery(tmp_path):
    spec = ServeSpec(
        graph=GraphSpec(n=300, deg=3, landmarks=8),
        stream=StreamSpec(batches=3, batch_size=30, queries=0,
                          microbatch=16, seed=3, quiet=True),
        topology=TopologySpec(readers=2, restart=True))
    topo = replica.ReplicaTopology(spec, str(tmp_path))
    killed = [False]

    def kill_once():
        # Mid-stream, not at the edges: the victim is likely holding an
        # in-flight batch, which must be requeued and answered elsewhere.
        if not killed[0] and time.monotonic() > t_kill[0]:
            killed[0] = True
            topo.kill_reader(0)

    try:
        topo.start()
        t_kill = [time.monotonic() + 1.0]
        report = replica.stream_queries(spec, topo, total=240, qps=120.0,
                                        on_tick=kill_once)
        assert killed[0]
        assert topo.updater_ok()
        assert topo.reader_restarts >= 1
        # No client-visible loss: every query either answered or (at
        # most transiently, while one reader was down) rejected.
        assert len(report.answers) + report.rejected == 240
        assert len(report.answers) >= 200
        assert report.max_staleness() <= 1
        # The heart of the contract: zero wrong answers, each checked
        # against Dijkstra on the graph at the version that served it.
        assert replica.verify_answers(str(tmp_path), report.answers) == 0
    finally:
        topo.stop()
