"""minitron-4b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — pruned nemotron (squared-ReLU MLP, ungated)
[arXiv:2407.14679; hf]."""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

ARCH_ID = "minitron-4b"
FAMILY = "lm"
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def model_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        d_head=128, d_ff=9216, vocab=256000,
        attn_pattern="full", act="relu2", gated=False,
        rope_theta=10000.0, dtype=jnp.bfloat16)


def reduced_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_model=48, n_heads=6,
        n_kv_heads=2, d_head=8, d_ff=96, vocab=512, attn_pattern="full",
        act="relu2", gated=False, dtype=jnp.float32,
        q_chunk=16, kv_chunk=16, loss_chunk=16)
