"""In-house AdamW + global-norm clipping + optional gradient compression.

No external optimizer deps. Optimizer state mirrors the param pytree
(m, v in f32) and shards with the same PartitionSpecs, so FSDP-sharded
params get FSDP-sharded optimizer state for free.

Gradient compression (`compress="int8_ef"`) implements int8 quantization
with error feedback: grads are quantized per-tensor before the (conceptual)
cross-replica reduction and the quantization residual is carried in the
optimizer state and added back next step — the standard bandwidth
optimization for gradient all-reduce at multi-pod scale (1-bit Adam / EF21
family). On a single host this is numerically identical to what runs on the
pod, so tests validate convergence with compression enabled.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress: str | None = None  # None | "int8_ef"


def init_opt_state(params: Any, cfg: AdamWConfig) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {"m": zeros,
             "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                               params),
             "step": jnp.zeros((), jnp.int32)}
    if cfg.compress == "int8_ef":
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def opt_state_shapes(params: Any, cfg: AdamWConfig) -> dict:
    """ShapeDtypeStruct mirror for dry-run lowering."""
    def f32_like(p):
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)
    state = {"m": jax.tree.map(f32_like, params),
             "v": jax.tree.map(f32_like, params),
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.compress == "int8_ef":
        state["ef"] = jax.tree.map(f32_like, params)
    return state


def _global_norm(tree: Any) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def _quantize_int8_ef(grads: Any, ef: Any):
    """Error-feedback int8 round-trip: returns (dequantized grads, new ef)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq, gf - deq
    pairs = jax.tree.map(one, grads, ef)
    deq = jax.tree.map(lambda p: p[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda p: p[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_ef


def adamw_update(params: Any, grads: Any, state: dict,
                 cfg: AdamWConfig) -> tuple[Any, dict]:
    if cfg.compress == "int8_ef":
        grads, new_ef = _quantize_int8_ef(grads, state["ef"])
    else:
        new_ef = None

    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    step = state["step"] + 1
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    is3 = lambda x: isinstance(x, tuple) and len(x) == 3  # noqa: E731
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=is3)
    new_state = {
        "m": jax.tree.map(lambda t: t[1], out, is_leaf=is3),
        "v": jax.tree.map(lambda t: t[2], out, is_leaf=is3),
        "step": step,
    }
    if new_ef is not None:
        new_state["ef"] = new_ef
    return new_params, new_state
