"""Tropical (min-plus) contraction kernel for Eq.-3 query upper bounds.

    out[b] = min_{i,j}  S[b,i] + H[i,j] + T[b,j]

This is the per-query hot path of the serving engine: for a query batch of
B pairs against R landmarks it does B·R² int32 add+min ops. On TPU the VPU
(8×128 lanes) executes the adds/mins; the landmark axes are padded to the
128-lane register width and the batch axis is tiled into VMEM blocks, so the
working set per grid step is  BB·RP·4 · 2 (S,T) + RP²·4 (H) + BB·RP·4 (acc)
≈ 0.4 MB for BB=256, RP=128 — far under the ~16 MB VMEM budget, leaving the
pipeline free to double-buffer blocks while the VPU runs.

H may be rectangular [P, R] with S [B, P]: that is the shard-local
contraction of `core/shard.py`'s model-sharded query bound — each shard
contracts its own P = R/M highway rows against the all-gathered target
labels and a `pmin` over the mesh finishes the reduction. P = R recovers
the full (unsharded) bound. INF padding is the min-plus identity, so the
padded contraction is exact.

The inner contraction loops over the PP rows of H instead of materialising
the [BB, PP, RP] cube (which would blow VMEM at 8 MB+ per block).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INF32 = 1 << 29  # plain int: pallas kernels must not capture traced constants

DEFAULT_BB = 256   # query-batch tile
LANES = 128        # TPU vector lane width; landmark axis padded to this


def _minplus_kernel(s_ref, h_ref, t_ref, o_ref):
    s = s_ref[...]          # [BB, PP] int32
    h = h_ref[...]          # [PP, RP]
    t = t_ref[...]          # [BB, RP]
    pp, rp = h.shape

    def body(i, acc):
        # acc[b, j] = min(acc[b, j], s[b, i] + h[i, j])
        s_col = jax.lax.dynamic_slice(s, (0, i), (s.shape[0], 1))   # [BB, 1]
        h_row = jax.lax.dynamic_slice(h, (i, 0), (1, rp))           # [1, RP]
        return jnp.minimum(acc, jnp.minimum(s_col + h_row, INF32))

    acc = jnp.full((s.shape[0], rp), INF32, jnp.int32)
    acc = jax.lax.fori_loop(0, pp, body, acc)
    o_ref[...] = jnp.min(jnp.minimum(acc + t, INF32), axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def minplus_pallas(s: jax.Array, h: jax.Array, t: jax.Array,
                   block_b: int = DEFAULT_BB,
                   interpret: bool = True) -> jax.Array:
    """S [B,P], H [P,R], T [B,R] int32 → out [B] int32.

    P = R is the full Eq.-3 bound; P < R is a shard-local partial bound
    (finished by a `pmin` across shards). Pads P and R→multiples of 128
    lanes (INF padding is the min-plus identity) and B→multiple of block_b.
    """
    b, p = s.shape
    p2, r = h.shape
    if p2 != p or t.shape != (b, r):
        raise ValueError(f"shape mismatch: S {s.shape}, H {h.shape}, "
                         f"T {t.shape}")
    pp = max(LANES, -(-p // LANES) * LANES)
    rp = max(LANES, -(-r // LANES) * LANES)
    bp = -(-b // block_b) * block_b

    pad_s = jnp.full((bp, pp), INF32, jnp.int32).at[:b, :p].set(s)
    pad_t = jnp.full((bp, rp), INF32, jnp.int32).at[:b, :r].set(t)
    pad_h = jnp.full((pp, rp), INF32, jnp.int32).at[:p, :r].set(h)

    out = pl.pallas_call(
        _minplus_kernel,
        grid=(bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, pp), lambda i: (i, 0)),
            pl.BlockSpec((pp, rp), lambda i: (0, 0)),
            pl.BlockSpec((block_b, rp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, 1), jnp.int32),
        interpret=interpret,
    )(pad_s, pad_h, pad_t)
    return out[:b, 0]
