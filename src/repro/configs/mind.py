"""mind [recsys]: embed_dim=64 n_interests=4 capsule_iters=3
multi-interest dynamic routing [arXiv:1904.08030; unverified].

Item table: ~10⁷ rows × 64 (huge-sparse-table regime, row-sharded;
10,485,760 = 512·20480 so the rows split evenly on every mesh)."""
from repro.models.mind import MindConfig

ARCH_ID = "mind"
FAMILY = "recsys"
SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")


def model_config() -> MindConfig:
    return MindConfig(name=ARCH_ID, n_items=10_485_760, embed_dim=64,
                      n_interests=4, capsule_iters=3, hist_len=50)


def reduced_config() -> MindConfig:
    return MindConfig(name=ARCH_ID + "-smoke", n_items=1000, embed_dim=16,
                      n_interests=4, capsule_iters=3, hist_len=10)
