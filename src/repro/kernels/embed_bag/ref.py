"""Pure-jnp oracle for embedding-bag (take + weighted segment reduce)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embed_bag(table: jax.Array, idx: jax.Array,
              weights: jax.Array) -> jax.Array:
    """out[b] = Σ_l weights[b,l] · table[idx[b,l]]."""
    rows = jnp.take(table, idx, axis=0)                       # [B, L, D]
    return jnp.einsum("bl,bld->bd", weights.astype(jnp.float32),
                      rows.astype(jnp.float32))
