"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff(expert)=1408
vocab=102400 — MLA kv_lora=512, 2 shared + 64 routed experts top-6
[arXiv:2405.04434; hf].

Spec note (also in DESIGN.md): the assignment line says both "MoE 64e
top-6" and "160 routed"; 160 routed is full V2 — we follow the primary
64-routed spec matching the HF v2-lite card. First layer is a dense FFN
(first_k_dense_replace=1), dense d_ff=10944.
"""
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig

ARCH_ID = "deepseek-v2-lite-16b"
FAMILY = "lm"
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def model_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID, n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_head=128, d_ff=10944, vocab=102400,
        attn_pattern="full",
        use_mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
        v_head_dim=128,
        moe=True, n_experts=64, n_shared_experts=2, top_k=6,
        d_ff_expert=1408, first_k_dense=1,
        act="silu", gated=True, rope_theta=10000.0, dtype=jnp.bfloat16)


def reduced_config() -> TransformerConfig:
    return TransformerConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_head=16, d_ff=128, vocab=512, attn_pattern="full",
        use_mla=True, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16,
        moe=True, n_experts=8, n_shared_experts=2, top_k=2, d_ff_expert=32,
        first_k_dense=1, act="silu", gated=True, dtype=jnp.float32,
        q_chunk=16, kv_chunk=16, loss_chunk=16)
