"""Launch-layer units: collective parsing, mesh construction, config
registry completeness — cheap tests that guard the dry-run tooling."""
from __future__ import annotations

import numpy as np

from repro.launch.dryrun import parse_collective_bytes, _shape_bytes


def test_parse_collective_bytes():
    hlo = """
  %ag = bf16[2,128]{1,0} all-gather(%x), replica_groups={}
  %ar = f32[64]{0} all-reduce(%y), to_apply=%sum
  %rs = f32[8,8]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = s32[16]{0} all-to-all(%w)
  %cp = pred[32]{0} collective-permute(%v)
  %plain = f32[100]{0} add(%a, %b)
"""
    out = parse_collective_bytes(hlo)
    assert out["per_type_bytes"]["all-gather"] == 2 * 128 * 2
    assert out["per_type_bytes"]["all-reduce"] == 64 * 4
    assert out["per_type_bytes"]["reduce-scatter"] == 64 * 4
    assert out["per_type_bytes"]["all-to-all"] == 16 * 4
    assert out["per_type_bytes"]["collective-permute"] == 32
    assert out["total_bytes"] == sum(out["per_type_bytes"].values())
    assert out["counts"]["all-gather"] == 1


def test_shape_bytes_scalars_and_dtypes():
    assert _shape_bytes("f32", "") == 4          # scalar
    assert _shape_bytes("bf16", "4,4") == 32
    assert _shape_bytes("pred", "8") == 8
    assert _shape_bytes("s8", "3,3") == 9


def test_registry_covers_all_assigned_archs():
    from repro.configs import common as cc
    assert len(cc.ALL_ARCHS) == 10
    for arch in cc.ALL_ARCHS:
        mod = cc.get_arch(arch)
        assert mod.ARCH_ID == arch
        assert len(mod.SHAPES) == 4
        assert mod.model_config() is not None
        assert mod.reduced_config() is not None


def test_lm_param_specs_match_param_shapes():
    """v1 and v2 spec pytrees must be structurally compatible with the
    parameter pytrees for every LM arch (guards sharding/shape drift)."""
    import jax
    from repro.configs import common as cc
    from repro.models import transformer as tfm
    for arch in ("gemma2-9b", "minitron-4b", "granite-8b",
                 "deepseek-v2-lite-16b", "mixtral-8x22b"):
        cfg = cc.get_arch(arch).model_config()
        shapes = tfm.param_shapes(cfg)
        for scheme in ("v1", "v2"):
            specs = tfm.param_specs(cfg, pod=False, scheme=scheme)
            def check(sh, sp):
                assert len(sp) <= len(sh.shape), (arch, scheme, sh, sp)
            jax.tree.map(check, shapes, specs,
                         is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
                         or hasattr(x, "_partitions"))
