from repro.kernels.edge_relax import kernel, ops, ref  # noqa: F401
